"""Paper Fig. 2 — ResNet8: normalized processing rate & latency vs #PUs
for LBLP / WB / RR / RD."""

from repro.models.cnn.graphs import resnet8_graph

from .common import PAPER_ALGS, csv_line, dump, print_sweep, sweep

# IMC:DPU ratio mirrors the node mix (10 IMC : 4 DPU nodes)
FLEETS = [(2, 1), (3, 1), (4, 2), (5, 2), (6, 3), (7, 3), (8, 3), (10, 4)]


def main() -> dict:
    res = sweep(resnet8_graph(), FLEETS, algs=PAPER_ALGS)
    print_sweep(res, "Fig.2 ResNet8 — normalized rate / latency vs #PUs")
    path = dump("fig2_resnet8", res)
    last = res["fleets"][-1]["algs"]
    first = res["fleets"][0]["algs"]
    for alg in PAPER_ALGS:
        csv_line(f"fig2.resnet8.{alg}.rate_fps@14pu", 0.0,
                 f"{last[alg]['rate_fps']:.1f}")
    csv_line("fig2.resnet8.lblp_vs_wb.rate_ratio@3pu", 0.0,
             f"{first['lblp']['rate_fps']/first['wb']['rate_fps']:.3f}")
    print(f"artifact: {path}")
    return res


if __name__ == "__main__":
    main()
