"""Kernel micro-benchmarks.

CPU container: wall-times are interpret-mode/oracle timings (the Pallas
kernels target TPU); the meaningful numbers here are the *roofline
estimates* computed from kernel arithmetic (MXU flops, VMEM traffic) for
the TPU target, plus oracle wall-times as a regression canary."""

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref

from .common import csv_line, dump

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> dict:
    out = {}
    key = jax.random.PRNGKey(0)
    print("kernel          M/B   K/S   N/hd  oracle_us  tpu_est_us  bound")
    for (M, K, N) in [(256, 512, 512), (1024, 1024, 1024),
                      (128, 4096, 4096)]:
        k1, k2 = jax.random.split(key)
        qx = jax.random.randint(k1, (M, K), -127, 128, dtype=jnp.int8)
        qw = jax.random.randint(k2, (K, N), -127, 128, dtype=jnp.int8)
        sw = jnp.full((N,), 0.01, jnp.float32)
        us = _time(lambda a, b: ref.imc_mvm_ref(a, b, jnp.float32(0.1), sw),
                   qx, qw)
        flops = 2.0 * M * K * N
        bytes_ = M * K + K * N + 4 * M * N
        t_c = flops / PEAK_FLOPS * 1e6
        t_m = bytes_ / HBM_BW * 1e6
        bound = "compute" if t_c > t_m else "memory"
        est = max(t_c, t_m)
        name = f"imc_mvm.{M}x{K}x{N}"
        print(f"imc_mvm    {M:6d} {K:5d} {N:5d} {us:10.1f} {est:11.2f}"
              f"  {bound}")
        csv_line(name, us, f"tpu_est={est:.2f}us,{bound}-bound")
        out[name] = {"oracle_us": us, "tpu_est_us": est, "bound": bound}

    for (B, H, S, hd) in [(2, 8, 1024, 128), (1, 8, 4096, 128)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)
        us = _time(lambda a, b, c: ref.flash_attention_ref(a, b, c), q, k, v)
        flops = 4.0 * B * H * S * S * hd
        bytes_ = 2 * (3 * B * H * S * hd + B * H * S * hd)
        t_c = flops / PEAK_FLOPS * 1e6
        t_m = bytes_ / HBM_BW * 1e6
        est = max(t_c, t_m)
        bound = "compute" if t_c > t_m else "memory"
        name = f"flash.{B}x{H}x{S}x{hd}"
        print(f"flash      {B:3d}x{H}  {S:5d} {hd:5d} {us:10.1f} {est:11.2f}"
              f"  {bound}")
        csv_line(name, us, f"tpu_est={est:.2f}us,{bound}-bound")
        out[name] = {"oracle_us": us, "tpu_est_us": est, "bound": bound}

    path = dump("kernel_bench", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    main()
