"""Beyond-paper: elastic rescheduling degradation curve — rate/latency
after successive PU failures, LBLP vs static (no-reschedule) baseline."""

from repro.core import make_pus
from repro.core.elastic import ElasticSession
from repro.models.cnn.graphs import resnet18_graph

from . import common
from .common import csv_line, dump


def main() -> dict:
    g = resnet18_graph()
    sess = ElasticSession(g, make_pus(8, 4), engine=common.SIM_MODE)
    out = {"events": []}
    print("event          n_pus  rate_fps  latency_ms")
    e0 = sess.history[0]
    print(f"initial        {e0.n_pus:5d} {e0.rate:9.0f} {e0.latency*1e3:10.2f}")
    for pid in (2, 4, 7, 1):
        ev = sess.fail(pid)
        out["events"].append({"failed": pid, "n_pus": ev.n_pus,
                              "rate": ev.rate, "latency": ev.latency})
        print(f"fail PU {pid:<6d} {ev.n_pus:5d} {ev.rate:9.0f}"
              f" {ev.latency*1e3:10.2f}")
        csv_line(f"elastic.rate_after_{ev.n_pus}pus", 0.0, f"{ev.rate:.0f}")
    retained = out["events"][-1]["rate"] / e0.rate
    print(f"rate retained after losing 4/12 PUs: {retained*100:.0f}% "
          f"(proportional share would be {8/12*100:.0f}%)")
    out["retained_fraction"] = retained
    path = dump("elastic_bench", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    main()
