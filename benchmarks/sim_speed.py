"""Simulator throughput benchmark: the perf trajectory's first point.

Measures the compiled event loop (``repro.core.simulator``) against the
frozen pre-compilation reference loop (``repro.core._sim_reference``) on
the workloads the acceptance criteria name:

* the **YOLOv8n 256-frame cell** (233 nodes, lblp on an 8+4 fleet,
  full ``run()``) — reference vs compiled-exact vs periodic early-exit,
  plus raw event-loop events/sec;
* a **multi-tenant cell** (2x ResNet-8 + ResNet-18 co-scheduled with
  lblp-mt on an 8+4 fleet) — the multi-stream early-exit trajectory
  point;
* the **simulator-driven suites of ``benchmarks.run`` at ``--frames
  64``** — every suite whose wall-clock the event loop determines, run
  twice with the suite-wide engine toggled (``common.SIM_MODE``)
  between ``"reference"`` and the current default.  The ``kernels``
  (jax hardware) and ``partition`` (no simulator) suites are excluded:
  their wall-clock is independent of the loop.

Writes ``BENCH_sim.json`` at the repo root (the perf-trajectory record)
and the usual artifact under ``artifacts/bench/``.

Perf gate: ``python -m benchmarks.sim_speed --check BENCH_sim.json``
re-measures and fails (exit 1) when any suite's reference-vs-default
speedup regressed more than ``CHECK_SLACK`` against the committed
baseline.  Speedup ratios — not absolute seconds — are compared, so the
gate is robust to CI runner speed.
"""

from __future__ import annotations

import importlib
import inspect
import io
import json
import os
import platform
import sys
import time
from contextlib import redirect_stdout

from repro.core import CostModel, MultiTenantGraph, get_scheduler, make_pus, make_simulator
from repro.models.cnn.graphs import resnet8_graph, resnet18_graph, yolov8n_graph

from . import common
from .common import csv_line, dump

#: benchmarks.run suites whose wall-clock the simulator determines
SIM_SUITES = (
    "fig2",
    "fig3",
    "table1",
    "fig4",
    "yolo",
    "quality",
    "elastic",
    "multi_tenant",
    "replication",
    "sensitivity",
)

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_sim.json")

#: allowed per-suite speedup regression before --check fails
CHECK_SLACK = 0.25

#: the PR 3 reference-engine suite times (the committed trajectory
#: baseline this PR's acceptance criteria are measured against; absolute
#: seconds, this machine class) — kept so later BENCH_sim.json rewrites
#: don't lose the anchor
PR3_REF_S = {
    "fig2": 0.7275,
    "fig3": 2.8692,
    "table1": 0.1913,
    "fig4": 1.0751,
    "yolo": 2.3114,
    "quality": 0.8195,
    "elastic": 0.1527,
    "multi_tenant": 1.5128,
    "replication": 0.7999,
    "sensitivity": 2.0891,
}


def _best(fn, repeats: int = 2) -> float:
    out = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        out = min(out, time.perf_counter() - t0)
    return out


def yolo_cell(frames: int) -> dict:
    g = yolov8n_graph()
    cm = CostModel()
    a = get_scheduler("lblp", cm).schedule(g, make_pus(8, 4))
    sims = {
        "reference": make_simulator(g, cm, engine="reference"),
        "exact": make_simulator(g, cm, engine="exact"),
        "periodic": make_simulator(g, cm, engine="periodic"),
    }
    cell: dict = {"graph": g.name, "nodes": len(g), "fleet": "8+4", "frames": frames}

    def run_once(s):
        # the compiled engines content-memoize run() on the shared
        # context; drop it so every repeat measures a full evaluation
        ctx = getattr(s, "_ctx", None)
        if ctx is not None:
            ctx.memo.clear()
        s.run(a, frames=frames)

    for name, sim in sims.items():
        cell[f"{name}_s"] = _best(lambda s=sim: run_once(s))
    cell["speedup_exact"] = cell["reference_s"] / cell["exact_s"]
    cell["speedup_periodic"] = cell["reference_s"] / cell["periodic_s"]
    cell["early_exit"] = sims["periodic"].last_early_exit

    # raw event-loop throughput (saturated pass only, no run() overhead)
    in_flight = 14
    ev = {}
    for name in ("reference", "exact"):
        sim = sims[name]
        dt = _best(lambda s=sim: s._simulate(a, frames=frames, in_flight=in_flight))
        ev[name] = sim.last_events / dt
    cell["events_per_sec"] = ev
    return cell


def mt_cell(frames: int) -> dict:
    """Multi-tenant trajectory point: 2x ResNet-8 + ResNet-18 co-served
    (mixed weights — the rn8 pair is weight-equal, rn18 rationalizes to
    a small fraction against them) under lblp-mt on an 8+4 fleet.

    The heterogeneous fair-queueing transient (the virtual-time gap
    drifting to its equilibrium) spans ~300 completions on this mix, so
    the steady-state exit only pays off at serving-scale frame budgets;
    the cell therefore runs at >= 512 frames per tenant."""
    frames = max(frames, 512)
    mt = MultiTenantGraph.union([resnet8_graph(), resnet8_graph(), resnet18_graph()])
    cm = CostModel()
    a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(8, 4))
    cell: dict = {
        "graph": "2x resnet8 + resnet18",
        "tenants": len(mt.tenants),
        "nodes": len(mt),
        "fleet": "8+4",
        "frames": frames,
    }
    in_flight = len(a.pus) + 2
    for name in ("reference", "exact", "periodic"):
        sim = make_simulator(mt, cm, engine=name)
        cell[f"{name}_s"] = _best(lambda s=sim: s._run_streams(a, frames, in_flight=in_flight))
        if name == "periodic":
            cell["early_exit"] = sim.last_early_exit
    cell["speedup_exact"] = cell["reference_s"] / cell["exact_s"]
    cell["speedup_periodic"] = cell["reference_s"] / cell["periodic_s"]
    return cell


def run_suites(frames: int, repeats: int = 2) -> dict:
    """Time the simulator-driven ``benchmarks.run`` suites under the
    reference engine and the current default, mimicking ``run.py``'s
    frame forwarding."""
    res: dict = {
        "frames": frames,
        "suites": {},
        "note": (
            "simulator-driven suites of benchmarks.run; kernels (jax) and "
            "partition (no simulator) excluded — their wall-clock is "
            "independent of the event loop"
        ),
    }
    from .run import SUITES

    default_mode = common.SIM_MODE
    try:
        for name in SIM_SUITES:
            module = importlib.import_module(f".{SUITES[name]}", package=__package__)
            fn = module.main
            kw = {}
            if "frames" in inspect.signature(fn).parameters:
                kw["frames"] = frames

            def run_once(fn=fn, kw=kw):
                with redirect_stdout(io.StringIO()):
                    fn(**kw)

            # the two engines are measured back to back per suite: the
            # ref/new *ratio* is the trajectory figure, and adjacent
            # measurement keeps runner speed drift out of it
            for engine, key in (("reference", "ref_s"), (default_mode, "new_s")):
                common.SIM_MODE = engine
                res["suites"].setdefault(name, {})[key] = _best(run_once, repeats)
    finally:
        common.SIM_MODE = default_mode
    for cell in res["suites"].values():
        cell["speedup"] = cell["ref_s"] / cell["new_s"]
    res["total_ref_s"] = sum(c["ref_s"] for c in res["suites"].values())
    res["total_new_s"] = sum(c["new_s"] for c in res["suites"].values())
    res["speedup"] = res["total_ref_s"] / res["total_new_s"]
    # the paper-figure sweeps are the deep-streaming workloads the early
    # exit targets; the full mix also carries multi-tenant runs (no
    # multi-stream exit yet) and scheduler-heavy suites, diluting it
    paper = ("fig2", "fig3", "fig4", "table1")
    res["paper_sweeps_ref_s"] = sum(res["suites"][n]["ref_s"] for n in paper)
    res["paper_sweeps_new_s"] = sum(res["suites"][n]["new_s"] for n in paper)
    res["paper_sweeps_speedup"] = res["paper_sweeps_ref_s"] / res["paper_sweeps_new_s"]
    res["engine"] = default_mode
    return res


def check_against(baseline_path: str, res: dict) -> int:
    """Perf gate: compare the just-measured per-suite speedups against a
    committed ``BENCH_sim.json``.  Returns the number of regressions
    beyond ``CHECK_SLACK`` (0 = gate passes).  Ratios are compared, not
    wall-clock, so the gate is machine-speed independent."""
    with open(baseline_path) as f:
        base = json.load(f)
    base_suites = base.get("run_frames64", {}).get("suites", {})
    new_suites = res["run_frames64"]["suites"]
    bad = 0
    print(f"== perf gate vs {baseline_path} (slack {CHECK_SLACK:.0%}) ==")
    for name, cell in sorted(new_suites.items()):
        ref = base_suites.get(name)
        if not ref or "speedup" not in ref:
            print(f"  {name:<14s} (no baseline entry, skipped)")
            continue
        # sub-quarter-second suites measure mostly scheduler + setup:
        # their ref/new ratio is noise-dominated, so they get double
        # slack (still catches any real 2x-class regression)
        slack = CHECK_SLACK if ref.get("ref_s", 1.0) >= 0.25 else 2 * CHECK_SLACK
        floor = ref["speedup"] * (1 - slack)
        ok = cell["speedup"] >= floor
        bad += not ok
        print(
            f"  {name:<14s} baseline {ref['speedup']:5.2f}x -> "
            f"measured {cell['speedup']:5.2f}x (floor {floor:5.2f}x) "
            f"{'ok' if ok else 'REGRESSED'}"
        )
    if bad:
        print(f"perf gate FAILED: {bad} suite(s) regressed > {CHECK_SLACK:.0%}")
    else:
        print("perf gate passed")
    return bad


def main(frames: int = 256, check: str | None = None) -> dict:
    out = {
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "yolo_cell": yolo_cell(frames),
        "mt_cell": mt_cell(frames),
        "run_frames64": run_suites(min(frames, 64), repeats=3 if check else 2),
    }
    yc = out["yolo_cell"]
    mc = out["mt_cell"]
    rf = out["run_frames64"]
    print(f"== sim_speed (engine: {common.SIM_MODE}) ==")
    print(
        f"yolo {yc['frames']}f cell: reference {yc['reference_s']:.3f}s | "
        f"exact {yc['exact_s']:.3f}s ({yc['speedup_exact']:.2f}x) | "
        f"periodic {yc['periodic_s']:.3f}s ({yc['speedup_periodic']:.2f}x, "
        f"early exit {yc['early_exit']})"
    )
    print(
        f"mt   {mc['frames']}f cell ({mc['graph']}): "
        f"reference {mc['reference_s']:.3f}s | "
        f"exact {mc['exact_s']:.3f}s ({mc['speedup_exact']:.2f}x) | "
        f"periodic {mc['periodic_s']:.3f}s ({mc['speedup_periodic']:.2f}x, "
        f"early exit {mc['early_exit']})"
    )
    eps = yc["events_per_sec"]
    print(
        f"event loop: {eps['reference'] / 1e3:.0f}k ev/s reference -> "
        f"{eps['exact'] / 1e3:.0f}k ev/s compiled"
    )
    print(
        f"benchmarks.run --frames {rf['frames']} (sim suites): "
        f"{rf['total_ref_s']:.1f}s reference -> {rf['total_new_s']:.1f}s "
        f"({rf['speedup']:.2f}x; paper-figure sweeps "
        f"{rf['paper_sweeps_speedup']:.2f}x)"
    )
    for name, cell in sorted(rf["suites"].items()):
        vs_pr3 = ""
        if name in PR3_REF_S:
            cell["pr3_ref_s"] = PR3_REF_S[name]
            cell["speedup_vs_pr3_ref"] = PR3_REF_S[name] / cell["new_s"]
            vs_pr3 = f"  [vs PR3 ref {cell['speedup_vs_pr3_ref']:5.2f}x]"
        print(
            f"  {name:<14s} {cell['ref_s']:7.2f}s -> {cell['new_s']:6.2f}s "
            f"({cell['speedup']:5.2f}x){vs_pr3}"
        )
    csv_line("sim_speed.yolo.speedup_periodic", 0.0, f"{yc['speedup_periodic']:.2f}x")
    csv_line("sim_speed.mt.speedup_periodic", 0.0, f"{mc['speedup_periodic']:.2f}x")
    csv_line("sim_speed.run_frames64.speedup", 0.0, f"{rf['speedup']:.2f}x")
    if check is not None:
        bad = check_against(check, out)
        if bad:
            # one full re-measure before failing: a throttled runner can
            # sink any single suite pass by more than the gate's slack
            print("re-measuring once to rule out runner noise ...")
            out["run_frames64"] = run_suites(min(frames, 64), repeats=3)
            bad = check_against(check, out)
        out["check"] = {"baseline": check, "regressions": bad}
        path = dump("sim_speed", out)
        print(f"artifact: {path}")
        if bad:
            raise SystemExit(1)
        return out
    with open(ROOT_JSON, "w") as f:
        json.dump(out, f, indent=2)
    path = dump("sim_speed", out)
    print(f"artifacts: {os.path.abspath(ROOT_JSON)}, {path}")
    return out


if __name__ == "__main__":
    args = sys.argv[1:]
    kw: dict = {}
    if "--frames" in args:
        i = args.index("--frames")
        kw["frames"] = int(args[i + 1])
        del args[i : i + 2]
    if "--check" in args:
        i = args.index("--check")
        kw["check"] = args[i + 1]
        del args[i : i + 2]
    if args:
        print("usage: python -m benchmarks.sim_speed [--frames N] [--check BASELINE.json]")
        raise SystemExit(2)
    main(**kw)
