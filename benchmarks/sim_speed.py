"""Simulator throughput benchmark: the perf trajectory's first point.

Measures the compiled event loop (``repro.core.simulator``) against the
frozen pre-compilation reference loop (``repro.core._sim_reference``) on
the workloads the acceptance criteria name:

* the **YOLOv8n 256-frame cell** (233 nodes, lblp on an 8+4 fleet,
  full ``run()``) — reference vs compiled-exact vs periodic early-exit,
  plus raw event-loop events/sec;
* the **simulator-driven suites of ``benchmarks.run`` at ``--frames
  64``** — every suite whose wall-clock the event loop determines, run
  twice with the suite-wide engine toggled (``common.SIM_MODE``)
  between ``"reference"`` and the current default.  The ``kernels``
  (jax hardware) and ``partition`` (no simulator) suites are excluded:
  their wall-clock is independent of the loop.

Writes ``BENCH_sim.json`` at the repo root (the perf-trajectory record)
and the usual artifact under ``artifacts/bench/``.
"""

from __future__ import annotations

import importlib
import inspect
import io
import json
import os
import platform
import time
from contextlib import redirect_stdout

from repro.core import CostModel, get_scheduler, make_pus, make_simulator
from repro.models.cnn.graphs import yolov8n_graph

from . import common
from .common import csv_line, dump

#: benchmarks.run suites whose wall-clock the simulator determines
SIM_SUITES = (
    "fig2",
    "fig3",
    "table1",
    "fig4",
    "yolo",
    "quality",
    "elastic",
    "multi_tenant",
    "replication",
    "sensitivity",
)

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_sim.json")


def _best(fn, repeats: int = 2) -> float:
    out = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        out = min(out, time.perf_counter() - t0)
    return out


def yolo_cell(frames: int) -> dict:
    g = yolov8n_graph()
    cm = CostModel()
    a = get_scheduler("lblp", cm).schedule(g, make_pus(8, 4))
    sims = {
        "reference": make_simulator(g, cm, engine="reference"),
        "exact": make_simulator(g, cm, engine="exact"),
        "periodic": make_simulator(g, cm, engine="periodic"),
    }
    cell: dict = {"graph": g.name, "nodes": len(g), "fleet": "8+4", "frames": frames}
    for name, sim in sims.items():
        cell[f"{name}_s"] = _best(lambda s=sim: s.run(a, frames=frames))
    cell["speedup_exact"] = cell["reference_s"] / cell["exact_s"]
    cell["speedup_periodic"] = cell["reference_s"] / cell["periodic_s"]
    cell["early_exit"] = sims["periodic"].last_early_exit

    # raw event-loop throughput (saturated pass only, no run() overhead)
    in_flight = 14
    ev = {}
    for name in ("reference", "exact"):
        sim = sims[name]
        dt = _best(lambda s=sim: s._simulate(a, frames=frames, in_flight=in_flight))
        ev[name] = sim.last_events / dt
    cell["events_per_sec"] = ev
    return cell


def run_suites(frames: int) -> dict:
    """Time the simulator-driven ``benchmarks.run`` suites under the
    reference engine and the current default, mimicking ``run.py``'s
    frame forwarding."""
    res: dict = {
        "frames": frames,
        "suites": {},
        "note": (
            "simulator-driven suites of benchmarks.run; kernels (jax) and "
            "partition (no simulator) excluded — their wall-clock is "
            "independent of the event loop"
        ),
    }
    from .run import SUITES

    default_mode = common.SIM_MODE
    try:
        for engine, key in (("reference", "ref_s"), (default_mode, "new_s")):
            common.SIM_MODE = engine
            for name in SIM_SUITES:
                module = importlib.import_module(f".{SUITES[name]}", package=__package__)
                fn = module.main
                kw = {}
                if "frames" in inspect.signature(fn).parameters:
                    kw["frames"] = frames

                def run_once(fn=fn, kw=kw):
                    with redirect_stdout(io.StringIO()):
                        fn(**kw)

                res["suites"].setdefault(name, {})[key] = _best(run_once)
    finally:
        common.SIM_MODE = default_mode
    for cell in res["suites"].values():
        cell["speedup"] = cell["ref_s"] / cell["new_s"]
    res["total_ref_s"] = sum(c["ref_s"] for c in res["suites"].values())
    res["total_new_s"] = sum(c["new_s"] for c in res["suites"].values())
    res["speedup"] = res["total_ref_s"] / res["total_new_s"]
    # the paper-figure sweeps are the deep-streaming workloads the early
    # exit targets; the full mix also carries multi-tenant runs (no
    # multi-stream exit yet) and scheduler-heavy suites, diluting it
    paper = ("fig2", "fig3", "fig4", "table1")
    res["paper_sweeps_ref_s"] = sum(res["suites"][n]["ref_s"] for n in paper)
    res["paper_sweeps_new_s"] = sum(res["suites"][n]["new_s"] for n in paper)
    res["paper_sweeps_speedup"] = res["paper_sweeps_ref_s"] / res["paper_sweeps_new_s"]
    res["engine"] = default_mode
    return res


def main(frames: int = 256) -> dict:
    out = {
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "yolo_cell": yolo_cell(frames),
        "run_frames64": run_suites(min(frames, 64)),
    }
    yc = out["yolo_cell"]
    rf = out["run_frames64"]
    print(f"== sim_speed (engine: {common.SIM_MODE}) ==")
    print(
        f"yolo {yc['frames']}f cell: reference {yc['reference_s']:.3f}s | "
        f"exact {yc['exact_s']:.3f}s ({yc['speedup_exact']:.2f}x) | "
        f"periodic {yc['periodic_s']:.3f}s ({yc['speedup_periodic']:.2f}x, "
        f"early exit {yc['early_exit']})"
    )
    eps = yc["events_per_sec"]
    print(
        f"event loop: {eps['reference'] / 1e3:.0f}k ev/s reference -> "
        f"{eps['exact'] / 1e3:.0f}k ev/s compiled"
    )
    print(
        f"benchmarks.run --frames {rf['frames']} (sim suites): "
        f"{rf['total_ref_s']:.1f}s reference -> {rf['total_new_s']:.1f}s "
        f"({rf['speedup']:.2f}x; paper-figure sweeps "
        f"{rf['paper_sweeps_speedup']:.2f}x)"
    )
    for name, cell in sorted(rf["suites"].items()):
        print(
            f"  {name:<14s} {cell['ref_s']:7.2f}s -> {cell['new_s']:6.2f}s "
            f"({cell['speedup']:5.2f}x)"
        )
    csv_line("sim_speed.yolo.speedup_periodic", 0.0, f"{yc['speedup_periodic']:.2f}x")
    csv_line("sim_speed.run_frames64.speedup", 0.0, f"{rf['speedup']:.2f}x")
    with open(ROOT_JSON, "w") as f:
        json.dump(out, f, indent=2)
    path = dump("sim_speed", out)
    print(f"artifacts: {os.path.abspath(ROOT_JSON)}, {path}")
    return out


if __name__ == "__main__":
    main()
