"""Benchmark harness entry point: one experiment per paper table/figure,
plus beyond-paper studies.  ``python -m benchmarks.run [names...]``

Prints ``CSV,name,us_per_call,derived`` lines for machine consumption and
writes JSON artifacts under artifacts/bench/.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (elastic_bench, fig2_resnet8, fig3_resnet18, fig4_imc_dpu,
                   kernel_bench, lm_partition, multi_tenant,
                   scheduler_quality, sensitivity, table1_utilization,
                   yolo_latency)

    suites = {
        "fig2": fig2_resnet8.main,
        "fig3": fig3_resnet18.main,
        "table1": table1_utilization.main,
        "fig4": fig4_imc_dpu.main,
        "yolo": yolo_latency.main,
        "quality": scheduler_quality.main,
        "kernels": kernel_bench.main,
        "elastic": elastic_bench.main,
        "multi_tenant": multi_tenant.main,
        "sensitivity": sensitivity.main,
        "partition": lm_partition.main,
    }
    want = sys.argv[1:] or list(suites)
    t0 = time.time()
    for name in want:
        if name not in suites:
            print(f"unknown suite '{name}'; have {sorted(suites)}")
            continue
        print(f"\n######## {name} ########")
        t1 = time.time()
        suites[name]()
        print(f"[{name} done in {time.time()-t1:.1f}s]")
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
