"""Benchmark harness entry point: one experiment per paper table/figure,
plus beyond-paper studies.  ``python -m benchmarks.run [--frames N] [names...]``

``--frames N`` forwards a small frame count to every suite that accepts
one — the CI smoke job uses it to catch benchmark bit-rot in seconds
instead of minutes.

Prints ``CSV,name,us_per_call,derived`` lines for machine consumption and
writes JSON artifacts under artifacts/bench/.
"""

from __future__ import annotations

import importlib
import inspect
import sys
import time

#: suite name -> module under benchmarks/ (imported lazily so the
#: stdlib-only suites run without jax — the CI smoke leg has none)
SUITES = {
    "fig2": "fig2_resnet8",
    "fig3": "fig3_resnet18",
    "table1": "table1_utilization",
    "fig4": "fig4_imc_dpu",
    "yolo": "yolo_latency",
    "quality": "scheduler_quality",
    "kernels": "kernel_bench",
    "elastic": "elastic_bench",
    "multi_tenant": "multi_tenant",
    "replication": "replication",
    "serving": "serving",
    "sensitivity": "sensitivity",
    "partition": "lm_partition",
    "sim_speed": "sim_speed",
}


def main() -> None:
    args = sys.argv[1:]
    frames = None
    if "--frames" in args:
        i = args.index("--frames")
        try:
            frames = int(args[i + 1])
        except (IndexError, ValueError):
            print("usage: python -m benchmarks.run [--frames N] [names...]")
            raise SystemExit(2)
        del args[i : i + 2]
    want = args or list(SUITES)
    t0 = time.time()
    for name in want:
        if name not in SUITES:
            print(f"unknown suite '{name}'; have {sorted(SUITES)}")
            continue
        module = importlib.import_module(f".{SUITES[name]}", package=__package__)
        fn = module.main
        kw = {}
        if frames is not None and "frames" in inspect.signature(fn).parameters:
            kw["frames"] = frames
        print(f"\n######## {name} ########")
        t1 = time.time()
        fn(**kw)
        print(f"[{name} done in {time.time()-t1:.1f}s]")
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
