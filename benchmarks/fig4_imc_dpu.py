"""Paper Fig. 4 — ResNet18 at a fixed 12-PU budget: rate & latency for
different IMC/DPU splits (chip-area allocation study), LBLP vs WB."""

from repro.models.cnn.graphs import resnet18_graph

from .common import csv_line, dump, print_sweep, sweep

TOTAL = 12
FLEETS = [(TOTAL - d, d) for d in (2, 3, 4, 6, 8)]


def main() -> dict:
    res = sweep(resnet18_graph(), FLEETS, algs=("lblp", "wb"), frames=128)
    print_sweep(res, "Fig.4 ResNet18 — fixed 12 PUs, varying #DPUs")
    for cell in res["fleets"]:
        d = cell["n_dpu"]
        ratio = cell["algs"]["lblp"]["rate_fps"] / cell["algs"]["wb"]["rate_fps"]
        csv_line(f"fig4.rate_ratio.dpu{d}", 0.0, f"{ratio:.3f}")
    path = dump("fig4_imc_dpu", res)
    print(f"artifact: {path}")
    return res


if __name__ == "__main__":
    main()
