"""Beyond-paper: SLO-aware serving control plane vs admit-all / static.

Replays seeded tenant-churn traces (arrivals with rate/latency SLOs,
departures, weight changes, a PU failure and rejoin) against three
serving policies over the same fleet:

* **slo-aware** — the full control plane (`repro.core.serving`):
  probe-gated admission, replica reclaim to make room, eviction repair
  after capacity loss, and replica autoscaling onto the hottest tenant.
* **admit-all** — every arrival is admitted and co-scheduled; no
  probes, no replicas.  Over-subscription shows up as SLO violations.
* **static** — the classic ops baseline: the fleet is evenly sliced,
  one tenant per slice (1+ IMC and 1+ DPU each), arrivals beyond the
  slice count are rejected, each tenant is scheduled alone with lblp.

The figure of merit is **goodput**: a tenant's attained rate counts
only at trace ticks where its SLO holds (a broken promise delivers no
value).  Expected outcome, asserted in the artifact: slo-aware meets
every admitted tenant's SLO on every cell (by construction — admission
is probe-gated and repair evicts on capacity loss) and attains at least
admit-all's aggregate goodput on most cells; its decision log is
bit-deterministic per seed.
"""

from __future__ import annotations

import random
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import CostModel, get_scheduler, make_pus, make_simulator
from repro.core.cost import PUSpec
from repro.core.graph import Graph, PUType
from repro.core.serving import (SLO, ServingControlPlane, SLOReport,
                                TraceEvent, aggregate_goodput)
from repro.models.cnn.graphs import resnet8_graph, resnet18_graph

from . import common
from .common import csv_line, dump

#: (fleet (n_imc, n_dpu), model mix, trace seed) — 8 cells
CELLS = [
    ((4, 2), ("resnet8",), 11),
    ((4, 2), ("resnet8",), 23),
    ((4, 2), ("resnet8", "resnet18"), 11),
    ((4, 2), ("resnet8", "resnet18"), 23),
    ((6, 3), ("resnet8",), 11),
    ((6, 3), ("resnet8",), 23),
    ((6, 3), ("resnet8", "resnet18"), 11),
    ((6, 3), ("resnet8", "resnet18"), 23),
]

ARRIVALS = 6


def solo_profile(models: Dict[str, Graph], fleet_shape: Tuple[int, int],
                 cm: CostModel, frames: int) -> Dict[str, Tuple[float, float]]:
    """Each model's solo full-fleet (rate, latency) — the deterministic
    calibration base the synthetic SLOs are fractions of."""
    out = {}
    fleet = make_pus(*fleet_shape)
    for name, g in models.items():
        a = get_scheduler("lblp", cm).schedule(g, fleet)
        r = make_simulator(g, cm, engine=common.SIM_MODE).run(a, frames=frames)
        out[name] = (r.rate, r.latency)
    return out


def synth_trace(seed: int, mix: Sequence[str],
                solo: Dict[str, Tuple[float, float]],
                fleet_shape: Tuple[int, int]) -> List[TraceEvent]:
    """Deterministic churn trace: ARRIVALS arrivals whose rate demands
    sum well past the fleet's capacity (so admission has something to
    decide), plus a weight change, a departure, and a PU failure that
    later rejoins.  Departure/load targets are drawn from all *arrived*
    names — a policy that rejected the target replays them as no-ops,
    keeping one trace comparable across policies."""
    rng = random.Random(seed)
    n_imc = fleet_shape[0]
    events: List[TraceEvent] = []
    names: List[str] = []
    failed_pu = rng.randrange(1, n_imc + 1)
    for i in range(ARRIVALS):
        model = mix[i % len(mix)]
        rate, lat = solo[model]
        frac = rng.choice([0.2, 0.35, 0.5, 0.75])
        max_lat = lat * rng.choice([50, 150, 400]) if rng.random() < 0.5 \
            else None
        name = f"{model}-{i}"
        names.append(name)
        events.append(TraceEvent(
            "arrive", tenant=name, model=model,
            slo=SLO(min_rate=rate * frac, max_latency=max_lat),
            weight=rng.choice([0.5, 1.0, 1.0, 2.0])))
        if i == 2:
            events.append(TraceEvent("fail", pu_id=failed_pu))
        if i == 3:
            events.append(TraceEvent("load", tenant=rng.choice(names),
                                     weight=rng.choice([0.5, 2.0])))
        if i == 4:
            events.append(TraceEvent("depart", tenant=rng.choice(names)))
            events.append(TraceEvent("join", pu_id=failed_pu,
                                     pu_type="imc"))
    return events


class StaticPartitionPlane:
    """Static-slicing baseline with the same trace/report interface as
    :class:`ServingControlPlane`: round-robin even fleet slices, one
    resident tenant per slice, admission = "a slice is free", repair =
    evict newest residents until the shrunken fleet slices again."""

    def __init__(self, pus: Sequence[PUSpec], models: Dict[str, Graph],
                 cost_model: Optional[CostModel] = None,
                 engine: str = "periodic", frames: int = 64) -> None:
        self.live: List[PUSpec] = list(pus)
        self.models = models
        self.cm = cost_model or CostModel()
        self.engine = engine
        self.frames = frames
        self.residents: List[Tuple[str, str]] = []   # (tenant, model)
        self.slos: Dict[str, SLO] = {}
        self.reports: Dict[str, SLOReport] = {}
        self.n_events = 0

    def _slices(self, n: int) -> Optional[List[List[PUSpec]]]:
        imc = [p for p in self.live if p.pu_type is PUType.IMC]
        dpu = [p for p in self.live if p.pu_type is PUType.DPU]
        if n == 0:
            return []
        if len(imc) < n or len(dpu) < n:
            return None
        return [imc[k::n] + dpu[k::n] for k in range(n)]

    def play(self, trace: Sequence[TraceEvent]) -> None:
        for ev in trace:
            self.step(ev)

    def step(self, ev: TraceEvent) -> None:
        index = self.n_events
        self.n_events += 1
        if ev.kind == "arrive":
            rep = self.reports[ev.tenant] = SLOReport(
                tenant=ev.tenant, slo=ev.slo, weight=ev.weight)
            if self._slices(len(self.residents) + 1) is None:
                rep.rejected_index = index
            else:
                self.residents.append((ev.tenant, ev.model))
                self.slos[ev.tenant] = ev.slo
                rep.admitted_index = index
        elif ev.kind == "depart" and ev.tenant in self.slos:
            self.residents = [r for r in self.residents
                              if r[0] != ev.tenant]
            self.slos.pop(ev.tenant)
            self.reports[ev.tenant].departed_index = index
        elif ev.kind == "load" and ev.tenant in self.slos:
            self.reports[ev.tenant].weight = ev.weight
        elif ev.kind == "fail":
            self.live = [p for p in self.live if p.pu_id != ev.pu_id]
            while self.residents and self._slices(len(self.residents)) is None:
                t, _ = self.residents.pop()       # evict newest
                self.slos.pop(t)
                self.reports[t].evicted_index = index
        elif ev.kind == "join":
            self.live.append(PUSpec(pu_id=ev.pu_id,
                                    pu_type=PUType(ev.pu_type),
                                    speed=ev.speed))
        self._sample(index)

    def _sample(self, index: int) -> None:
        slices = self._slices(len(self.residents))
        if not slices:
            return
        for (tenant, model), sl in zip(self.residents, slices):
            g = self.models[model]
            a = get_scheduler("lblp", self.cm).schedule(g, sl)
            r = make_simulator(g, self.cm, engine=self.engine).run(
                a, frames=self.frames)
            h = self.slos[tenant].headroom(r.rate, r.latency)
            self.reports[tenant].samples.append(
                (index, r.rate, r.latency, h))


def run_cell(fleet_shape, mix, seed, models, cm, frames) -> dict:
    solo = solo_profile({m: models[m] for m in mix}, fleet_shape, cm, frames)
    trace = synth_trace(seed, mix, solo, fleet_shape)

    def fresh(admission: bool, autoscale: bool) -> ServingControlPlane:
        return ServingControlPlane(
            make_pus(*fleet_shape), models, cost_model=cm,
            engine=common.SIM_MODE, frames=frames,
            admission=admission, autoscale=autoscale)

    aware = fresh(True, True)
    aware.play(trace)
    admit_all = fresh(False, False)
    admit_all.play(trace)
    static = StaticPartitionPlane(make_pus(*fleet_shape), models,
                                  cost_model=cm, engine=common.SIM_MODE,
                                  frames=frames)
    static.play(trace)

    # determinism: an identically configured replay of the same trace
    # must produce a bit-identical audit artifact
    replay = fresh(True, True)
    replay.play(trace)
    deterministic = replay.audit_json() == aware.audit_json()

    def summarize(reports, n_events, plane=None) -> dict:
        _, mean = aggregate_goodput(reports, n_events)
        admitted = [r for r in reports.values()
                    if r.admitted_index is not None]
        return {
            "goodput": mean,
            "admitted": len(admitted),
            "rejected": sum(1 for r in reports.values()
                            if r.rejected_index is not None),
            "evicted": sum(1 for r in reports.values()
                           if r.evicted_index is not None),
            "violation_ticks": sum(
                len(range(v[0], v[1] + 1))
                for r in reports.values() for v in r.violations),
            "all_admitted_slos_met": all(r.satisfied() for r in admitted),
            **({"decisions": len(plane.decisions),
                "probes": plane.probes} if plane is not None else {}),
        }

    return {
        "n_imc": fleet_shape[0], "n_dpu": fleet_shape[1],
        "mix": "+".join(mix), "seed": seed,
        "events": len(trace),
        "deterministic": deterministic,
        "slo_aware": summarize(aware.reports, aware.n_events, aware),
        "admit_all": summarize(admit_all.reports, admit_all.n_events),
        "static": summarize(static.reports, static.n_events),
    }


def main(frames: int = 96) -> dict:
    cm = CostModel()
    # one graph object per model (a registry): every plane, probe and
    # baseline over the same model shares compiled contexts and memos
    models = {"resnet8": resnet8_graph(), "resnet18": resnet18_graph()}
    out = {"frames": frames, "cells": []}
    print(f"{'cell':<24s} {'policy':>10s} {'goodput':>9s} {'adm':>4s} "
          f"{'rej':>4s} {'evi':>4s} {'viol':>5s} {'slos_met':>8s}")
    for fleet_shape, mix, seed in CELLS:
        cell = run_cell(fleet_shape, mix, seed, models, cm, frames)
        out["cells"].append(cell)
        label = (f"{cell['mix']} {cell['n_imc']}+{cell['n_dpu']} "
                 f"s{cell['seed']}")
        for policy in ("slo_aware", "admit_all", "static"):
            s = cell[policy]
            print(f"{label:<24s} {policy:>10s} {s['goodput']:9.0f} "
                  f"{s['admitted']:4d} {s['rejected']:4d} {s['evicted']:4d} "
                  f"{s['violation_ticks']:5d} "
                  f"{str(s['all_admitted_slos_met']):>8s}")
            label = ""
        csv_line(
            f"serving.{cell['mix'].replace('+', '_')}"
            f".{cell['n_imc']}+{cell['n_dpu']}.s{cell['seed']}",
            0.0,
            f"{cell['slo_aware']['goodput'] / max(cell['admit_all']['goodput'], 1e-9):.3f}")

    cells = out["cells"]
    met_all = sum(1 for c in cells if c["slo_aware"]["all_admitted_slos_met"])
    beats = sum(1 for c in cells
                if c["slo_aware"]["goodput"]
                >= c["admit_all"]["goodput"] * (1 - 1e-9))
    beats_static = sum(1 for c in cells
                       if c["slo_aware"]["goodput"]
                       >= c["static"]["goodput"] * (1 - 1e-9))
    det = sum(1 for c in cells if c["deterministic"])
    out["cells_slos_met"] = met_all
    out["cells_geq_admit_all"] = beats
    out["cells_geq_static"] = beats_static
    out["cells_deterministic"] = det
    print(f"\nslo-aware meets every admitted SLO on {met_all}/{len(cells)} "
          f"cells; goodput >= admit-all on {beats}/{len(cells)}, "
          f">= static on {beats_static}/{len(cells)}; "
          f"deterministic replay on {det}/{len(cells)}")
    path = dump("serving", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    kw = {}
    if "--frames" in sys.argv:
        kw["frames"] = int(sys.argv[sys.argv.index("--frames") + 1])
    main(**kw)
