"""Beyond-paper: scheduler quality study.

Compares the paper's four algorithms against HEFT / CPOP (related work
[12]), our LBLP-X variant, and — on ResNet8/18-sized graphs — the
branch-and-bound optimum of the pipeline bottleneck.  Reports the
optimality gap of each heuristic."""

import time

from repro.core import CostModel, get_scheduler, make_pus
from repro.models.cnn.graphs import resnet8_graph, resnet18_graph

from .common import csv_line, dump, make_sim

ALGS = ("lblp", "wb", "rr", "rd", "heft", "cpop", "lblp-x")


def main() -> dict:
    cm = CostModel()
    out = {}
    for g, fleets in ((resnet8_graph(), [(4, 2), (7, 3)]),
                      (resnet18_graph(), [(8, 4)])):
        sim = make_sim(g, cm)
        for n_imc, n_dpu in fleets:
            fleet = make_pus(n_imc, n_dpu)
            key = f"{g.name}@{n_imc}+{n_dpu}"
            try:
                t0 = time.perf_counter()
                opt = get_scheduler("optimal", cm).schedule(g, fleet)
                opt_b = opt.bottleneck(g, cm)
                opt_us = (time.perf_counter() - t0) * 1e6
            except ValueError:
                opt_b, opt_us = None, 0.0
            rows = {}
            print(f"\n== {key} (optimal bottleneck: "
                  f"{opt_b*1e6 if opt_b else float('nan'):.1f}us) ==")
            print("alg      rate_fps  latency_us  bneck_gap  sched_us")
            for alg in ALGS:
                t0 = time.perf_counter()
                a = get_scheduler(alg, cm).schedule(g, fleet)
                us = (time.perf_counter() - t0) * 1e6
                r = sim.run(a, frames=96)
                gap = (a.bottleneck(g, cm) / opt_b - 1.0) if opt_b else None
                rows[alg] = {"rate_fps": r.rate, "latency_s": r.latency,
                             "bottleneck_gap": gap, "schedule_time_us": us}
                print(f"{alg:8s} {r.rate:8.1f} {r.latency*1e6:11.1f} "
                      f"{(gap*100 if gap is not None else float('nan')):8.2f}% "
                      f"{us:9.1f}")
                csv_line(f"quality.{g.name}.{alg}.sched", us,
                         f"gap={gap if gap is not None else 'n/a'}")
            out[key] = {"optimal_bottleneck": opt_b,
                        "optimal_time_us": opt_us, "algs": rows}
    path = dump("scheduler_quality", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    main()
