"""Beyond-paper: the paper's technique on the LM tier — LBLP-driven
pipeline-stage partitioning of the 10 assigned architectures vs naive
uniform layer chunking.

For heterogeneous stacks (MoE routers vs experts, RG-LRU vs attention
blocks, enc vs dec) uniform chunking mis-balances stages; LBLP's
load-balance objective (projected to contiguous stages) recovers the
balance.  Reported: per-stage load imbalance (max/mean) for both."""

from repro.configs import all_archs, get_config
from repro.core.pipeline_partition import partition, transformer_block_graph

from .common import csv_line, dump


def uniform_imbalance(cfg, n_stages: int, seq_len: int = 4096) -> float:
    g = transformer_block_graph(cfg, seq_len)
    order = g.topo_order()
    from repro.core.pipeline_partition import _flops_cost_model
    cm = _flops_cost_model()
    costs = [cm.time(g.nodes[n]) for n in order]
    per = len(order) // n_stages
    loads = []
    for s in range(n_stages):
        lo = s * per
        hi = (s + 1) * per if s < n_stages - 1 else len(order)
        loads.append(sum(costs[lo:hi]))
    mean = sum(loads) / n_stages
    return max(loads) / mean if mean else 1.0


def main() -> dict:
    out = {}
    n_stages = 8
    print(f"pipeline partitioning into {n_stages} stages (imbalance = "
          "max stage load / mean)")
    print(f"{'arch':24s} {'uniform':>9s} {'lblp':>9s}  winner")
    for arch in all_archs():
        cfg = get_config(arch)
        u = uniform_imbalance(cfg, n_stages)
        plan = partition(cfg, n_stages)
        winner = "lblp" if plan.imbalance < u - 1e-9 else (
            "tie" if abs(plan.imbalance - u) <= 1e-9 else "uniform")
        out[arch] = {"uniform": u, "lblp": plan.imbalance, "winner": winner}
        print(f"{arch:24s} {u:9.3f} {plan.imbalance:9.3f}  {winner}")
        csv_line(f"partition.{arch}", 0.0,
                 f"uniform={u:.3f},lblp={plan.imbalance:.3f}")
    wins = sum(1 for v in out.values() if v["winner"] == "lblp")
    print(f"\nLBLP strictly better on {wins}/{len(out)} archs "
          "(ties occur on perfectly homogeneous dense stacks)")
    path = dump("lm_partition", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    main()
