"""Paper §V.C — YOLOv8n: LBLP-vs-WB latency gap.

The paper: the subset is mostly sequential, parallel branches bound the
schedulable-parallelism effect to ~10% of latency; they measured up to a
6% latency difference.  We report the isolated-inference gap (pure
branch-parallelism effect, <=10% bound) and the streaming sojourn gap
(queueing included), which bracket the paper's protocol."""

from repro.core import CostModel, get_scheduler, make_pus
from repro.models.cnn.graphs import yolov8n_graph

from .common import csv_line, dump, make_sim

FLEETS = [(8, 4), (12, 6), (16, 8), (24, 12)]


def main() -> dict:
    g = yolov8n_graph()
    cm = CostModel()
    sim = make_sim(g, cm)
    crit = g.critical_time(lambda n: cm.time(n))
    total = sum(cm.time(n) for n in g.nodes.values() if not n.is_free())
    out = {"off_path_share": (total - crit) / total, "fleets": []}
    print("== YOLOv8n LBLP vs WB ==")
    print(f"off-critical-path work: {out['off_path_share']*100:.1f}% of total "
          "(paper: parallelism affects at most ~10% of latency)")
    print("PUs   isolated-gap%  streaming-gap%  rate lblp/wb")
    for n_imc, n_dpu in FLEETS:
        fleet = make_pus(n_imc, n_dpu)
        res = {}
        for alg in ("lblp", "wb"):
            a = get_scheduler(alg, cm).schedule(g, fleet)
            res[alg] = sim.run(a, frames=48)
        iso = abs(res["wb"].latency_isolated - res["lblp"].latency_isolated) \
            / min(r.latency_isolated for r in res.values())
        strm = abs(res["wb"].latency - res["lblp"].latency) \
            / min(r.latency for r in res.values())
        rr = res["lblp"].rate / res["wb"].rate
        out["fleets"].append({
            "n_imc": n_imc, "n_dpu": n_dpu, "isolated_gap": iso,
            "streaming_gap": strm, "rate_ratio": rr,
        })
        print(f"{n_imc+n_dpu:3d}   {iso*100:11.2f}  {strm*100:13.2f}  {rr:10.2f}")
        csv_line(f"yolo.latency_gap_isolated.pu{n_imc+n_dpu}", 0.0,
                 f"{iso*100:.2f}%")
    print("paper: measured gap up to 6%")
    path = dump("yolo_latency", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    main()
