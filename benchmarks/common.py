"""Shared benchmark utilities: experiment runners + artifact dumping.

All suites take their simulator from :func:`make_sim`, which honors the
module-level ``SIM_MODE``: ``"periodic"`` by default (the compiled
quantized loop with steady-state early exit — see
``repro.core.simulator``), overridable to ``"exact"`` or ``"reference"``
via the ``REPRO_SIM_MODE`` environment variable or by assignment (the
``sim_speed`` suite toggles it to measure honest before/after).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, Iterable, Tuple

from repro.core import (CostModel, get_scheduler, make_pus, make_simulator,
                        normalize)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

PAPER_ALGS = ("lblp", "wb", "rr", "rd")
EXTRA_ALGS = ("lblp-x", "heft", "cpop")

#: simulation engine used by every suite ("periodic" | "exact" | "reference")
SIM_MODE = os.environ.get("REPRO_SIM_MODE", "periodic")


def make_sim(graph, cm: CostModel | None = None):
    """Simulator over ``graph`` on the suite-wide ``SIM_MODE`` engine."""
    return make_simulator(graph, cm, engine=SIM_MODE)


def sweep(graph, fleets: Iterable[Tuple[int, int]], algs=PAPER_ALGS,
          frames: int = 96) -> Dict:
    """Run ``algs`` over PU fleets; returns nested result dict."""
    cm = CostModel()
    sim = make_sim(graph, cm)
    out: Dict = {"graph": graph.name, "fleets": []}
    for n_imc, n_dpu in fleets:
        fleet = make_pus(n_imc, n_dpu)
        cell = {"n_imc": n_imc, "n_dpu": n_dpu, "algs": {}}
        group = {}
        for alg in algs:
            t0 = time.perf_counter()
            a = get_scheduler(alg, cm).schedule(graph, fleet)
            sched_us = (time.perf_counter() - t0) * 1e6
            r = sim.run(a, frames=frames)
            group[alg] = r
            cell["algs"][alg] = {
                "rate_fps": r.rate,
                "latency_s": r.latency,
                "latency_isolated_s": r.latency_isolated,
                "interval_s": r.interval,
                "mean_utilization": r.mean_utilization,
                "utilization": {str(k): v for k, v in r.utilization.items()},
                "schedule_time_us": sched_us,
            }
        for alg, pt in normalize(group).items():
            cell["algs"][alg]["norm_rate"] = pt.norm_rate
            cell["algs"][alg]["norm_latency"] = pt.norm_latency
        out["fleets"].append(cell)
    return out


def print_sweep(res: Dict, title: str) -> None:
    print(f"\n== {title} ==")
    algs = list(res["fleets"][0]["algs"])
    hdr = "PUs(imc+dpu) " + "  ".join(f"{a:>22s}" for a in algs)
    print(hdr)
    print(" " * 13 + "  ".join(f"{'nrate / nlat':>22s}" for _ in algs))
    for cell in res["fleets"]:
        label = f"{cell['n_imc']+cell['n_dpu']:3d} ({cell['n_imc']}+{cell['n_dpu']})"
        row = []
        for a in algs:
            d = cell["algs"][a]
            row.append(f"{d['norm_rate']:10.3f} / {d['norm_latency']:8.3f}")
        print(f"{label:<13s}" + "  ".join(f"{r:>22s}" for r in row))


def dump(name: str, payload: Dict) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return os.path.abspath(path)


def csv_line(name: str, us_per_call: float, derived: str) -> None:
    """Harness convention: ``name,us_per_call,derived``."""
    print(f"CSV,{name},{us_per_call:.3f},{derived}")
