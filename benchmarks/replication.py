"""Beyond-paper: LRMP-style bottleneck layer replication (lblp-r vs lblp).

Sweeps the replica budget and reports the processing-rate gain from
replicating longest-path bottleneck nodes into spare PU capacity
(``Graph.replicate`` round-robin frame splitting, `lblp-r` greedy
budgeted search).  Workloads: ResNet-8 and ResNet-18 single-tenant, plus
the heterogeneous two-tenant serving mix (resnet8+resnet18 co-scheduled
with lblp-mt as the replication base).

``lblp-r`` is run with measured-rate validation (``validate_rate``), so
every cell satisfies rate(lblp-r) >= rate(baseline): the scheduler
reverts to the plain schedule whenever the analytic bound gain fails to
materialize in the discrete-event simulator (finite in-flight buffering
can eat a small bound gain through longer sojourns).  The interesting
figure is how much of the fleet's idle capacity a replica budget
converts into throughput — on fleets with more PUs than heavy layers,
~2x is available (see artifacts/bench/replication.json).
"""

from __future__ import annotations

from repro.core import CostModel, MultiTenantGraph, get_scheduler, make_pus
from repro.core.schedulers.lblp_r import LBLPRScheduler, measured_rate
from repro.models.cnn.graphs import resnet8_graph, resnet18_graph

from . import common
from .common import csv_line, dump

BUDGETS = (1, 2, 4, 8)


def sweep_cell(g, fleet_shape, cm, frames, base_alg):
    n_imc, n_dpu = fleet_shape
    fleet = make_pus(n_imc, n_dpu)
    base_a = get_scheduler(base_alg, cm).schedule(g, fleet)
    base_rate = measured_rate(g, base_a, cm, frames, engine=common.SIM_MODE)
    rows = []
    for budget in BUDGETS:
        sched = LBLPRScheduler(cm, replica_budget=budget,
                               validate_rate=frames,
                               sim_engine=common.SIM_MODE)
        a = sched.schedule(g, fleet)
        g_r = a.meta["replicated_graph"]
        rate = measured_rate(g_r, a, cm, frames, engine=common.SIM_MODE)
        rows.append({
            "budget": budget,
            "rate_base": base_rate,
            "rate_lblp_r": rate,
            "gain": rate / base_rate if base_rate > 0 else 1.0,
            "replicas": {str(k): v for k, v in a.meta["replicas"].items()},
            "extra_replicas": a.meta["extra_replicas"],
            "bound_base": max(base_a.load(g, cm).values()),
            "bound_lblp_r": a.meta["bound_interval"],
        })
    return rows


def main(frames: int = 96) -> dict:
    cm = CostModel()
    workloads = [
        ("resnet8", resnet8_graph(), (8, 4), "lblp"),
        ("resnet8", resnet8_graph(), (12, 6), "lblp"),
        ("resnet18", resnet18_graph(), (12, 6), "lblp"),
        ("resnet18", resnet18_graph(), (16, 8), "lblp"),
        ("rn8+rn18",
         MultiTenantGraph.union([resnet8_graph(), resnet18_graph()]),
         (8, 4), "lblp-mt"),
        ("rn8+rn18",
         MultiTenantGraph.union([resnet8_graph(), resnet18_graph()]),
         (12, 6), "lblp-mt"),
    ]
    out = {"frames": frames, "budgets": list(BUDGETS), "cells": []}
    print(f"{'workload':<10s} {'fleet':>7s} {'budget':>7s} {'base_fps':>9s} "
          f"{'lblp-r':>9s} {'gain':>6s}  replicas")
    for name, g, fleet_shape, base_alg in workloads:
        rows = sweep_cell(g, fleet_shape, cm, frames, base_alg)
        for row in rows:
            out["cells"].append({
                "workload": name,
                "n_imc": fleet_shape[0], "n_dpu": fleet_shape[1],
                **row,
            })
            print(f"{name:<10s} {fleet_shape[0]}+{fleet_shape[1]:<4d} "
                  f"{row['budget']:7d} {row['rate_base']:9.0f} "
                  f"{row['rate_lblp_r']:9.0f} {row['gain']:6.2f}  "
                  f"{row['replicas']}")
            csv_line(
                f"replication.{name}.{fleet_shape[0]}+{fleet_shape[1]}"
                f".b{row['budget']}",
                0.0, f"{row['gain']:.3f}")
    geq = sum(1 for c in out["cells"] if c["rate_lblp_r"] >= c["rate_base"])
    improved = sum(1 for c in out["cells"]
                   if c["rate_lblp_r"] > c["rate_base"] * 1.01)
    out["cells_geq_base"] = geq
    out["cells_improved"] = improved
    print(f"\nlblp-r >= lblp on {geq}/{len(out['cells'])} cells; "
          f"{improved} improved > 1%")
    path = dump("replication", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    main()
