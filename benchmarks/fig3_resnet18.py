"""Paper Fig. 3 — ResNet18: normalized processing rate & latency vs #PUs
for LBLP / WB / RR / RD."""

from repro.models.cnn.graphs import resnet18_graph

from .common import PAPER_ALGS, csv_line, dump, print_sweep, sweep

# ~2:1 IMC:DPU (Table I uses 8+4 at 12 total); top out at 30 (= #nodes)
FLEETS = [(2, 1), (4, 2), (6, 3), (8, 4), (10, 5), (14, 7), (21, 9)]


def main() -> dict:
    res = sweep(resnet18_graph(), FLEETS, algs=PAPER_ALGS, frames=128)
    print_sweep(res, "Fig.3 ResNet18 — normalized rate / latency vs #PUs")
    path = dump("fig3_resnet18", res)
    cell12 = next(c for c in res["fleets"] if c["n_imc"] + c["n_dpu"] == 12)
    ratio_rate = cell12["algs"]["lblp"]["rate_fps"] / cell12["algs"]["wb"]["rate_fps"]
    ratio_lat = cell12["algs"]["wb"]["latency_s"] / cell12["algs"]["lblp"]["latency_s"]
    csv_line("fig3.resnet18.lblp_vs_wb.rate_ratio@12pu", 0.0, f"{ratio_rate:.3f}")
    csv_line("fig3.resnet18.wb_vs_lblp.latency_ratio@12pu", 0.0, f"{ratio_lat:.3f}")
    print(f"paper check: rate ratio {ratio_rate:.2f} (paper >2), "
          f"latency ratio {ratio_lat:.2f} (paper ~1.4)")
    print(f"artifact: {path}")
    return res


if __name__ == "__main__":
    main()
