"""Beyond-paper: cost-model sensitivity study.

The paper's conclusions are measured on one FPGA calibration.  Here we
sweep the hardware profile (crossbar MVM latency, DPU throughput,
interconnect bandwidth, crossbars per PU) an order of magnitude in each
direction and check whether the paper's headline orderings survive:

  * LBLP >= WB/RR/RD in rate at 12 PUs (ResNet18),
  * LBLP rate gain over WB stays > 2x,
  * LBLP latency <= all others.

This is the reproduction-robustness experiment the paper itself could
not run (one chip calibration); it shows the claims are properties of
the *algorithm*, not the calibration point."""

from dataclasses import replace

from repro.core import CostModel, get_scheduler, make_pus
from repro.core.cost import IMCE_DEFAULT
from repro.models.cnn.graphs import resnet18_graph

from .common import csv_line, dump, make_sim

SWEEPS = {
    "t_mvm": [50e-9, 250e-9, 1000e-9],
    "dpu_elem_rate": [0.5e9, 2.0e9, 8.0e9],
    "dram_bw": [2e9, 8e9, 32e9],
    "xbars_per_pu": [1, 4, 16],
}


def main() -> dict:
    g = resnet18_graph()
    out = {"points": []}
    print("param            value      lblp/wb-rate  lblp-best-rate  lblp-best-lat")
    worst_ratio = float("inf")
    for param, values in SWEEPS.items():
        for v in values:
            prof = replace(IMCE_DEFAULT, name=f"{param}={v}", **{param: v})
            cm = CostModel(prof)
            fleet = make_pus(8, 4, prof)
            sim = make_sim(g, cm)
            res = {}
            for alg in ("lblp", "wb", "rr", "rd"):
                a = get_scheduler(alg, cm).schedule(g, fleet)
                res[alg] = sim.run(a, frames=96)
            ratio = res["lblp"].rate / res["wb"].rate
            best_rate = res["lblp"].rate >= max(
                r.rate for r in res.values()) * 0.999
            best_lat = res["lblp"].latency <= min(
                r.latency for r in res.values()) * 1.001
            worst_ratio = min(worst_ratio, ratio)
            out["points"].append({
                "param": param, "value": v, "lblp_wb_ratio": ratio,
                "lblp_best_rate": bool(best_rate),
                "lblp_best_latency": bool(best_lat),
            })
            print(f"{param:16s} {v:9.3g} {ratio:13.2f} {str(best_rate):>15s}"
                  f" {str(best_lat):>14s}")
            csv_line(f"sensitivity.{param}.{v:g}", 0.0, f"ratio={ratio:.2f}")
    out["worst_lblp_wb_ratio"] = worst_ratio
    all_best = all(p["lblp_best_rate"] for p in out["points"])
    print(f"\nLBLP best-rate at EVERY calibration point: {all_best}")
    print(f"worst LBLP/WB rate ratio across sweep: {worst_ratio:.2f} "
          "(paper claims >2 at its single calibration)")
    path = dump("sensitivity", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    main()
