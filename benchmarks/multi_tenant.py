"""Beyond-paper: multi-tenant co-scheduling vs static fleet partitioning.

Several CNNs resident on one PU fleet at once, each with its own frame
stream.  Two deployment policies:

* **static**   — the fleet is partitioned evenly; every model gets its own
  slice and is scheduled alone on it with LBLP (the obvious "one model per
  sub-fleet" ops policy).
* **co-sched** — the tagged union of all models is placed on the *whole*
  fleet by one scheduler (lblp-mt, with rr/wb as baselines) and all
  streams share every PU.

Co-scheduling can always emulate the partition, so its aggregate rate
should match or beat static; the win grows when tenants are heterogeneous
(a static slice sized for the light model idles while the heavy model's
slice saturates).  Per-tenant rate/latency come from the multi-tenant
simulator's ``SimResult.tenants``.
"""

from __future__ import annotations

from repro.core import CostModel, MultiTenantGraph, get_scheduler, make_pus
from repro.models.cnn.graphs import resnet8_graph, resnet18_graph

from .common import csv_line, dump, make_sim

CO_ALGS = ("lblp-mt", "rr", "wb")


def split_fleet_evenly(n_imc: int, n_dpu: int, n_tenants: int):
    """Round-robin the fleet into ``n_tenants`` disjoint slices.

    Every slice keeps the global PU ids (slice k gets IMC PUs k,
    k+n_tenants, ... and likewise DPUs) so static results stay comparable.
    """
    full = make_pus(n_imc, n_dpu)
    imc = [p for p in full if p.pu_type.value == "imc"]
    dpu = [p for p in full if p.pu_type.value == "dpu"]
    return [imc[k::n_tenants] + dpu[k::n_tenants] for k in range(n_tenants)]


def static_partition(graphs, tenants, n_imc: int, n_dpu: int, cm: CostModel,
                     frames: int) -> dict:
    """One model per fleet slice; keyed by the union's deduplicated tenant
    names so duplicate models stay distinct entries."""
    slices = split_fleet_evenly(n_imc, n_dpu, len(graphs))
    per_tenant = {}
    for g, tenant, sl in zip(graphs, tenants, slices):
        if not sl:
            raise ValueError("fleet too small to give every tenant a slice")
        a = get_scheduler("lblp", cm).schedule(g, sl)
        r = make_sim(g, cm).run(a, frames=frames)
        per_tenant[tenant] = {"rate": r.rate, "latency": r.latency,
                              "n_pus": len(sl)}
    return {
        "aggregate_rate": sum(v["rate"] for v in per_tenant.values()),
        "tenants": per_tenant,
    }


def co_scheduled(mt: MultiTenantGraph, n_imc: int, n_dpu: int, alg: str,
                 cm: CostModel, frames: int) -> dict:
    fleet = make_pus(n_imc, n_dpu)
    a = get_scheduler(alg, cm).schedule(mt, fleet)
    r = make_sim(mt, cm).run(a, frames=frames)
    return {
        "aggregate_rate": sum(m.rate for m in r.tenants.values()),
        "mean_utilization": r.mean_utilization,
        "tenants": {t: {"rate": m.rate, "latency": m.latency,
                        "utilization_share": m.utilization_share}
                    for t, m in r.tenants.items()},
    }


def main(frames: int = 96) -> dict:
    cm = CostModel()
    # one graph object per resident model (a model registry): workloads
    # that serve the same model share its compiled simulation context,
    # cached schedules and memoized runs across cells
    rn8_a, rn8_b, rn18 = resnet8_graph(), resnet8_graph(), resnet18_graph()
    workloads = [
        ("2x resnet8", [rn8_a, rn8_b]),
        ("resnet8+resnet18", [rn8_a, rn18]),
        ("2x rn8 + rn18", [rn8_a, rn8_b, rn18]),
    ]
    fleets = [(4, 2), (8, 4), (12, 6)]
    out = {"fleets": [], "frames": frames}
    for wl_name, graphs in workloads:
        mt = MultiTenantGraph.union(graphs)
        for n_imc, n_dpu in fleets:
            if n_imc < len(graphs) or n_dpu < len(graphs):
                continue  # static baseline needs one PU of each type per tenant
            cell = {"workload": wl_name, "n_imc": n_imc, "n_dpu": n_dpu}
            cell["static"] = static_partition(graphs, mt.tenants, n_imc,
                                              n_dpu, cm, frames)
            for alg in CO_ALGS:
                cell[alg] = co_scheduled(mt, n_imc, n_dpu, alg, cm, frames)
            out["fleets"].append(cell)

    print(f"{'workload':<18s} {'fleet':>7s} {'static':>9s} "
          + "".join(f"{a:>9s}" for a in CO_ALGS) + "   co/static")
    for cell in out["fleets"]:
        s = cell["static"]["aggregate_rate"]
        co = cell["lblp-mt"]["aggregate_rate"]
        row = (f"{cell['workload']:<18s} {cell['n_imc']}+{cell['n_dpu']:<4d} "
               f"{s:9.0f}" + "".join(
                   f"{cell[a]['aggregate_rate']:9.0f}" for a in CO_ALGS))
        print(row + f" {co / s:10.2f}x")
        csv_line(
            f"multi_tenant.{cell['workload'].replace(' ', '')}"
            f".{cell['n_imc']}+{cell['n_dpu']}",
            0.0, f"{co / s:.3f}")
    # per-tenant detail for the heterogeneous 8+4 cell
    detail = next(c for c in out["fleets"]
                  if c["workload"] == "resnet8+resnet18"
                  and (c["n_imc"], c["n_dpu"]) == (8, 4))
    print("\nper-tenant (resnet8+resnet18, 8+4 fleet, lblp-mt co-schedule):")
    print(f"{'tenant':<16s} {'rate_fps':>9s} {'lat_ms':>8s} {'util_share':>11s}"
          f" {'static_fps':>11s}")
    for t, m in detail["lblp-mt"]["tenants"].items():
        st_rate = detail["static"]["tenants"][t]["rate"]
        print(f"{t:<16s} {m['rate']:9.0f} {m['latency']*1e3:8.2f} "
              f"{m['utilization_share']:11.2f} {st_rate:11.0f}")
    wins = sum(1 for c in out["fleets"]
               if c["lblp-mt"]["aggregate_rate"]
               >= c["static"]["aggregate_rate"] * 0.99)
    print(f"\nco-scheduled lblp-mt >= static on {wins}/{len(out['fleets'])} cells")
    out["wins"] = wins
    path = dump("multi_tenant", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    main()
