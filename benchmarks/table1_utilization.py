"""Paper Table I — ResNet18 on 12 PUs (8 IMC + 4 DPU): per-PU node
placement, normalized weights area, and utilization for LBLP vs WB."""

from repro.core import CostModel, get_scheduler, make_pus
from repro.core.graph import PUType
from repro.models.cnn.graphs import resnet18_graph

from .common import csv_line, dump, make_sim


def main() -> dict:
    g = resnet18_graph()
    cm = CostModel()
    sim = make_sim(g, cm)
    fleet = make_pus(8, 4)
    out = {}
    for alg in ("lblp", "wb"):
        a = get_scheduler(alg, cm).schedule(g, fleet)
        r = sim.run(a, frames=128)
        weights = a.weights(g)
        wmax = max(weights[p] for p in range(1, 9)) or 1.0
        rows = []
        print(f"\n== Table I ({alg.upper()}) — IMC PUs ==")
        print("PU  nodes                      weights%  util%")
        for p in range(1, 9):
            nodes = [n for n in a.nodes_on(p)
                     if g.nodes[n].pu_type == PUType.IMC]
            rows.append({
                "pu": p, "nodes": nodes,
                "weights_pct": 100.0 * weights[p] / wmax,
                "utilization_pct": 100.0 * r.utilization[p],
            })
            print(f"{p:<3d} {str(nodes):<26s} {rows[-1]['weights_pct']:7.1f} "
                  f"{rows[-1]['utilization_pct']:6.1f}")
        imc_mean = sum(r.utilization[p] for p in range(1, 9)) / 8
        all_mean = r.mean_utilization
        print(f"mean IMC-PU utilization: {imc_mean*100:.1f}%   "
              f"(all-PU: {all_mean*100:.1f}%)")
        out[alg] = {"rows": rows, "imc_mean_util": imc_mean,
                    "all_mean_util": all_mean, "rate_fps": r.rate,
                    "latency_s": r.latency}
        csv_line(f"table1.{alg}.imc_mean_util_pct", 0.0, f"{imc_mean*100:.1f}")
    print("\npaper: LBLP 78.3% vs WB 24.4% mean utilization")
    path = dump("table1_utilization", out)
    print(f"artifact: {path}")
    return out


if __name__ == "__main__":
    main()
