"""Replicate the bottleneck layer: graph -> lblp-r -> simulate.

Walks the LRMP-style replication flow end to end: schedule ResNet-8 with
plain LBLP, inspect the bottleneck PU, then let lblp-r greedily clone the
longest-path bottleneck nodes into spare PU capacity (round-robin frame
splitting) and compare processing rate before/after.

    PYTHONPATH=src python examples/replicate_bottleneck.py
"""

from repro.core import CostModel, IMCESimulator, get_scheduler, make_pus, schedule_replicated
from repro.models.cnn.graphs import resnet8_graph


def main() -> None:
    graph = resnet8_graph()
    cm = CostModel()
    fleet = make_pus(n_imc=12, n_dpu=6)  # spare capacity to replicate into

    # 1. plain LBLP: the bound is one heavy layer no placement can split
    base = get_scheduler("lblp", cm).schedule(graph, fleet)
    base_r = IMCESimulator(graph, cm).run(base, frames=96)
    load = base.load(graph, cm)
    bottleneck = max(load, key=load.get)
    print(
        f"lblp: rate {base_r.rate:.0f} fps, "
        f"bound {base_r.bound_interval*1e6:.0f} us "
        f"(PU {bottleneck} holds {base.nodes_on(bottleneck)})"
    )

    # 2. lblp-r: clone bottleneck nodes until the balance gain flattens
    g_r, repl = schedule_replicated(graph, fleet, cm)
    print(f"lblp-r replicas (base node -> count): {repl.meta['replicas']}")
    for base_id, members in sorted(g_r.replica_groups().items()):
        names = [g_r.nodes[m].name for m in members]
        print(f"  node {base_id}: {names}")

    # 3. simulate the replicated graph: frame f runs on replica f % k
    repl_r = IMCESimulator(g_r, cm).run(repl, frames=96)
    print(
        f"lblp-r: rate {repl_r.rate:.0f} fps, "
        f"bound {repl_r.bound_interval*1e6:.0f} us "
        f"({repl_r.rate / base_r.rate:.2f}x lblp)"
    )


if __name__ == "__main__":
    main()
