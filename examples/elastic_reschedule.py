"""Elastic scaling demo: PUs die one by one, LBLP re-places the network
each time, and the processing rate degrades gracefully; a replacement PU
joins and the rate recovers.

    PYTHONPATH=src python examples/elastic_reschedule.py
"""

from repro.core import PUSpec, PUType, make_pus
from repro.core.elastic import ElasticSession
from repro.models.cnn.graphs import resnet18_graph


def main() -> None:
    sess = ElasticSession(resnet18_graph(), make_pus(8, 4))
    ev0 = sess.history[0]
    print(f"initial: {ev0.n_pus} PUs rate={ev0.rate:.0f} fps "
          f"latency={ev0.latency*1e3:.2f} ms")

    for pid in (2, 7, 5):
        ev = sess.fail(pid)
        print(f"PU {pid} died -> reschedule: {ev.n_pus} PUs "
              f"rate={ev.rate:.0f} fps latency={ev.latency*1e3:.2f} ms")

    ev = sess.join(PUSpec(pu_id=20, pu_type=PUType.IMC))
    print(f"spare IMC PU joined -> {ev.n_pus} PUs rate={ev.rate:.0f} fps")

    print("\ndegradation curve (n_pus, rate, latency_ms):")
    for n, r, lat in sess.degradation_curve():
        print(f"  {n:3d}  {r:8.0f}  {lat*1e3:8.2f}")


if __name__ == "__main__":
    main()
