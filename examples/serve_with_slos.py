"""Serve a churning tenant population under SLOs: the control plane.

Walkthrough of the serving tier (`repro.core.serving`):

  1. stand up a ServingControlPlane over an 8 IMC + 4 DPU fleet with a
     two-model registry,
  2. replay a hand-written trace: three arrivals with rate/latency
     promises (one of them too greedy — it gets rejected), a PU
     failure, a priority bump, a departure,
  3. read the audit trail: every decision with its reason, and a
     per-tenant SLOReport of promise vs attainment.

The plane probes every candidate state in the simulator before
committing, reclaims replicas to make room for admissible newcomers,
and spends spare capacity on the hottest tenant's bottleneck layers
(LRMP-style replication) — watch for the "replicate" decisions.

Run: PYTHONPATH=src python examples/serve_with_slos.py
"""

from repro.core import CostModel, make_pus
from repro.core.serving import SLO, ServingControlPlane, TraceEvent
from repro.models.cnn.graphs import resnet8_graph, resnet18_graph


def main() -> None:
    cm = CostModel()
    models = {"resnet8": resnet8_graph(), "resnet18": resnet18_graph()}
    plane = ServingControlPlane(make_pus(8, 4), models, cost_model=cm,
                                engine="periodic", frames=64)

    trace = [
        # a camera pipeline: modest rate floor, real latency ceiling
        TraceEvent("arrive", tenant="cam-0", model="resnet8",
                   slo=SLO(min_rate=300.0, max_latency=0.05)),
        # a bulk classifier: throughput only, double priority
        TraceEvent("arrive", tenant="bulk-0", model="resnet18",
                   slo=SLO(min_rate=400.0), weight=2.0),
        # too greedy for what is left — expect a rejection
        TraceEvent("arrive", tenant="greedy", model="resnet8",
                   slo=SLO(min_rate=5000.0)),
        TraceEvent("fail", pu_id=3),
        TraceEvent("load", tenant="cam-0", weight=2.0),
        TraceEvent("join", pu_id=3, pu_type="imc"),
        TraceEvent("depart", tenant="bulk-0"),
    ]
    plane.play(trace)

    print("== decision log ==")
    for d in plane.decisions:
        print(f"[{d.index}] {d.event:<12s} {d.action:<9s} "
              f"{(d.tenant or '-'):<8s} {d.reason}")

    print("\n== SLO reports ==")
    print(f"{'tenant':<8s} {'promise':<28s} {'outcome':<10s} "
          f"{'worst rate':>10s} {'violations'}")
    for t, r in sorted(plane.reports.items()):
        promise = []
        if r.slo.min_rate:
            promise.append(f">={r.slo.min_rate:.0f} fps")
        if r.slo.max_latency:
            promise.append(f"<={r.slo.max_latency * 1e3:.0f} ms")
        if r.rejected_index is not None:
            outcome = "rejected"
        elif r.evicted_index is not None:
            outcome = "evicted"
        else:
            outcome = "satisfied" if r.satisfied() else "violated"
        worst = min((s[1] for s in r.samples), default=float("nan"))
        print(f"{t:<8s} {', '.join(promise):<28s} {outcome:<10s} "
              f"{worst:10.0f} {r.violations}")

    print(f"\n{plane.probes} what-if probes over {plane.n_events} trace "
          f"events; final replicas {plane.replicas}")


if __name__ == "__main__":
    main()
