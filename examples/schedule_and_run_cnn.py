"""End-to-end CNN deployment: schedule ResNet18 with LBLP, execute the
*scheduled graph* numerically (float + INT8), and show that numerics are
placement-invariant while timing follows the schedule.

    PYTHONPATH=src python examples/schedule_and_run_cnn.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CostModel, IMCESimulator, get_scheduler, make_pus
from repro.models.cnn import executor, graphs, resnet


def main() -> None:
    cfg = resnet.RESNET18_CIFAR
    graph = graphs.build_resnet_graph(cfg)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32, 3))

    cm = CostModel()
    fleet = make_pus(8, 4)
    assignment = get_scheduler("lblp", cm).schedule(graph, fleet)
    sim = IMCESimulator(graph, cm)
    res = sim.run(assignment, frames=96)

    print(f"{graph.name}: {len(graph)} nodes on {len(fleet)} PUs (LBLP)")
    print(f"  simulated rate    : {res.rate:.0f} fps")
    print(f"  simulated latency : {res.latency*1e3:.2f} ms")
    print(f"  mean utilization  : {res.mean_utilization*100:.1f}%")

    ref = resnet.forward(params, x, cfg)
    y_float = executor.execute(graph, params, x, mode="float")
    y_int8 = executor.execute(graph, params, x, mode="int8")
    np.testing.assert_allclose(np.asarray(y_float), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    agree = float(jnp.mean((jnp.argmax(y_float, -1)
                            == jnp.argmax(y_int8, -1)).astype(jnp.float32)))
    print("  float graph == reference model: exact")
    print(f"  INT8 top-1 agreement vs float : {agree*100:.0f}%")


if __name__ == "__main__":
    main()
