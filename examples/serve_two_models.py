"""Serve two CNNs from one PU fleet: co-schedule, stream, survive a failure.

Walkthrough of the multi-tenant tier:

  1. build the tagged union of ResNet-8 and ResNet-18,
  2. co-place it on a 8 IMC + 4 DPU fleet with lblp-mt,
  3. drive both frame streams — saturated, then open-loop at a camera-ish
     30 fps for one tenant while the other takes the leftovers,
  4. kill a PU: the elastic session re-co-schedules *all* tenants at once.

Run: PYTHONPATH=src python examples/serve_two_models.py
"""

from repro.core import (CostModel, MultiTenantGraph, MultiTenantSimulator,
                        get_scheduler, make_pus)
from repro.core.elastic import ElasticSession
from repro.models.cnn.graphs import resnet8_graph, resnet18_graph


def show(title: str, result) -> None:
    print(f"\n-- {title} --")
    print(f"{'tenant':<16s} {'rate_fps':>9s} {'lat_ms':>8s} {'util_share':>11s}")
    for t, m in result.tenants.items():
        print(f"{t:<16s} {m.rate:9.0f} {m.latency*1e3:8.2f} "
              f"{m.utilization_share:11.2f}")


def main() -> None:
    mt = MultiTenantGraph.union([resnet8_graph(), resnet18_graph()])
    cm = CostModel()
    fleet = make_pus(8, 4)
    print(f"union: {len(mt)} nodes, tenants {mt.tenants}")

    a = get_scheduler("lblp-mt", cm).schedule(mt, fleet)
    bn = a.tenant_bottleneck(mt, cm)
    print("per-tenant load bound:",
          {t: f"{v*1e6:.0f}us" for t, v in bn.items()})

    sim = MultiTenantSimulator(mt, cm)
    show("saturated (closed-loop) co-serving", sim.run(a, frames=64))

    rates = {"resnet8": 30.0, "resnet18_cifar": 1000.0}
    show(f"open-loop injection {rates}", sim.run(a, frames=64, rates=rates))

    print("\n-- PU 3 fails: one elastic pass re-places every tenant --")
    sess = ElasticSession(mt, fleet, cost_model=cm)
    ev = sess.fail(3)
    print(f"{'tenant':<16s} {'rate_fps':>9s} {'lat_ms':>8s}")
    for t in mt.tenants:
        print(f"{t:<16s} {ev.tenant_rates[t]:9.0f} "
              f"{ev.tenant_latencies[t]*1e3:8.2f}")


if __name__ == "__main__":
    main()
