"""Serve a small LM with batched requests through the continuous-batching
server (prefill + lockstep decode, failure-recovery path included).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.lm import transformer
from repro.runtime.serve_loop import Request, Server


def main() -> None:
    cfg = get_config("gemma3-1b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    server = Server(cfg, params, max_batch=4, s_max=96)

    rng = jax.random.PRNGKey(1)
    requests = []
    for i in range(10):
        rng, sub = jax.random.split(rng)
        plen = int(jax.random.randint(sub, (), 4, 24))
        prompt = jax.random.randint(sub, (plen,), 0, cfg.vocab,
                                    dtype=jnp.int32)
        requests.append(Request(rid=i, prompt=prompt, max_new=8))

    t0 = time.time()
    stats = server.serve(requests)
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in requests)
    print(f"served {stats.served} requests, {total_new} new tokens, "
          f"{stats.prefills} prefills, {stats.decode_steps} decode steps "
          f"in {dt:.1f}s ({total_new/dt:.1f} tok/s on CPU)")
    for r in requests[:3]:
        print(f"  req {r.rid}: prompt[{r.prompt.shape[0]}] -> {r.out_tokens}")


if __name__ == "__main__":
    main()
