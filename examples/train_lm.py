"""End-to-end driver: train a ~100M-parameter decoder LM for a few
hundred steps on the synthetic pipeline, with checkpointing, resume, and
gradient-compression stats (deliverable b, training kind).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--resume]
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import Segment, ShapeSpec
from repro.optim import adamw
from repro.runtime.train_loop import TrainLoopConfig, train


def make_100m_config():
    """~100M params: stablelm-family geometry scaled down."""
    base = get_config("stablelm-1.6b")
    return dataclasses.replace(
        base,
        name="stablelm-100m",
        d_model=640,
        n_heads=10,
        n_kv_heads=10,
        head_dim=64,
        d_ff=1792,
        vocab=32768,
        segments=(Segment("attn", 12),),
        microbatch=8,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = make_100m_config()
    from repro.models.lm.transformer import param_count
    print(f"model: {cfg.name}, {param_count(cfg)/1e6:.1f}M params, "
          f"{jax.device_count()} device(s)")

    shape = ShapeSpec("example", args.seq, args.batch, "train")
    loop = TrainLoopConfig(
        total_steps=args.steps,
        ckpt_every=50,
        ckpt_dir=args.ckpt_dir,
        log_every=20,
        opt=adamw.AdamWConfig(lr=3e-4, warmup_steps=30,
                              total_steps=args.steps),
    )
    report = train(cfg, shape, loop)
    first = sum(report.losses[:10]) / max(len(report.losses[:10]), 1)
    last = sum(report.losses[-10:]) / max(len(report.losses[-10:]), 1)
    print(f"\nsteps={report.steps_run} resumed_from={report.resumed_from}")
    print(f"loss: first10={first:.4f} -> last10={last:.4f} "
          f"({report.wall_seconds:.1f}s)")
    assert last < first, "training did not reduce loss"
    print("OK: loss decreased on the synthetic stream")


if __name__ == "__main__":
    main()
