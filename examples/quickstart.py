"""Quickstart: schedule ResNet8 onto a hybrid IMC/DPU fleet with the
paper's four algorithms and compare (the paper's core experiment in ~30
lines).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (CostModel, IMCESimulator, get_scheduler, make_pus,
                        normalize, utilization_table)
from repro.models.cnn.graphs import resnet8_graph


def main() -> None:
    graph = resnet8_graph()
    cm = CostModel()
    fleet = make_pus(n_imc=4, n_dpu=2)          # 6-PU hybrid device
    sim = IMCESimulator(graph, cm)

    print(f"{graph.name}: {len(graph)} nodes "
          f"({graph.num_nodes(kind=None)} total, "
          f"{graph.total_weight_bytes()/1e3:.0f} KB weights)\n")

    results = {}
    for alg in ("lblp", "wb", "rr", "rd"):
        assignment = get_scheduler(alg, cm).schedule(graph, fleet)
        assignment.validate(graph, cm, check_capacity=False)
        results[alg] = sim.run(assignment, frames=96)

    print("alg     rate[fps]  latency[ms]  norm_rate  norm_lat  mean_util")
    for alg, pt in normalize(results).items():
        print(f"{alg:6s} {pt.rate:10.1f} {pt.latency*1e3:12.3f}"
              f" {pt.norm_rate:10.3f} {pt.norm_latency:9.3f}"
              f" {pt.mean_utilization*100:9.1f}%")

    print("\nLBLP per-PU utilization:")
    print(utilization_table(results["lblp"]))


if __name__ == "__main__":
    main()
