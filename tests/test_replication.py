"""LRMP-style layer replication: graph transform structure, round-robin
frame routing in the simulator, amortized cost accounting, the lblp-r
greedy scheduler, and the elastic replica-absorb fast path.

Deterministic tests run everywhere; hypothesis variants widen the
"replication never lowers the analytic bound" invariant over random
graphs when the [test] extra is installed.
"""


import pytest

from repro.core.cost import CostModel, HardwareProfile, make_pus
from repro.core.elastic import ElasticSession
from repro.core.graph import Graph, GraphError, MultiTenantGraph, OpKind
from repro.core.schedulers import get_scheduler, schedule_replicated
from repro.core.schedulers.lblp_r import LBLPRScheduler
from repro.core.simulator import IMCESimulator, MultiTenantSimulator

from helpers import build_random_graph, given, settings, st

ROOMY = HardwareProfile(name="roomy", pu_weight_capacity=1e12)


def chain(n_vectors_list, name="chain"):
    g = Graph(name)
    prev = None
    for i, nv in enumerate(n_vectors_list):
        n = g.add(f"c{i}", OpKind.CONV, flops=1e6, weight_bytes=1e3,
                  out_bytes=2e3, out_elems=2e3,
                  meta=dict(cin_kk=64, cout=64, n_vectors=nv))
        if prev is not None:
            g.add_edge(prev, n.node_id)
        prev = n.node_id
    return g


class TestReplicateTransform:
    def test_clones_structure_and_meta(self):
        g = chain([64, 256, 64])
        g2 = g.replicate(2, 3)
        assert len(g) == 3 and len(g2) == 5     # original untouched
        group = g2.replica_groups()[2]
        assert len(group) == 3
        for i, m in enumerate(sorted(group, key=lambda x: g2.nodes[x].meta["replica_index"])):
            node = g2.nodes[m]
            assert node.replica_count == 3
            assert node.replica_index == i
            assert node.replica_group == 2
            assert node.flops == g.nodes[2].flops
            assert g2.predecessors(m) == g.predecessors(2)
            assert g2.successors(m) == g.successors(2)
        # unreplicated nodes report count 1
        assert g2.nodes[1].replica_count == 1
        assert g2.nodes[1].replica_index is None

    def test_rejects_bad_replication(self):
        g = chain([64, 64])
        with pytest.raises(GraphError):
            g.replicate(1, 0)
        with pytest.raises(GraphError):
            g.replicate(1, 2).replicate(1, 2)   # already replicated
        g.add("out", OpKind.OUTPUT, deps=[2])
        with pytest.raises(GraphError):
            g.replicate(3, 2)                   # structural node
        with pytest.raises(KeyError):
            g.replicate(99, 2)

    def test_with_replicas_and_copy_semantics(self):
        g = chain([64, 256, 512])
        g2 = g.with_replicas({2: 2, 3: 3})
        assert g2 is not g and len(g) == 3
        assert {b: len(m) for b, m in g2.replica_groups().items()} == {2: 2, 3: 3}
        # empty counts still copies
        g3 = g.with_replicas({})
        assert g3 is not g and len(g3) == len(g)

    def test_drop_replica_reindexes_and_unreplicates(self):
        g = chain([64, 256, 64]).replicate(2, 3)
        members = g.replica_groups()[2]
        g2 = g.drop_replica(members[1])
        left = g2.replica_groups()[2]
        assert len(left) == 2
        assert sorted(g2.nodes[m].meta["replica_index"] for m in left) == [0, 1]
        assert all(g2.nodes[m].replica_count == 2 for m in left)
        g3 = g2.drop_replica(left[0])
        assert not g3.replica_groups()
        survivor = [n for n in g3.nodes
                    if g3.nodes[n].name.startswith("c1")][0]
        assert g3.nodes[survivor].replica_count == 1
        with pytest.raises(GraphError):
            g3.drop_replica(1)                  # not a replica

    def test_json_round_trip_keeps_replica_tags(self):
        g = chain([64, 256, 64]).replicate(2, 2)
        rt = Graph.from_json(g.to_json())
        assert rt.replica_groups() == g.replica_groups()

    def test_multi_tenant_replication_keeps_tenant_registry(self):
        mt = MultiTenantGraph.union(
            [chain([64, 256], "a"), chain([64, 512], "b")])
        base = mt.tenant_nodes("b")[1]          # b's heavy conv
        mt2 = mt.replicate(base, 2)
        assert isinstance(mt2, MultiTenantGraph)
        assert mt2.tenants == ["a", "b"]
        new = set(mt2.tenant_nodes("b")) - set(mt.tenant_nodes("b"))
        assert len(new) == 1
        (rid,) = new
        assert mt2.tenant_of(rid) == "b"
        assert rid in mt2.tenant_sinks("b") or rid in mt2.tenant_nodes("b")
        # round trip keeps the replica inside the tenant
        rt = MultiTenantGraph.from_json(mt2.to_json())
        assert set(rt.tenant_nodes("b")) == set(mt2.tenant_nodes("b"))
        # dropping it restores the original node set
        mt3 = mt2.drop_replica(rid)
        assert set(mt3.tenant_nodes("b")) == set(mt.tenant_nodes("b"))


class TestAmortizedAccounting:
    def test_frame_time_divides_by_replica_count(self):
        g = chain([64, 256, 64])
        cm = CostModel(ROOMY)
        t = cm.time(g.nodes[2])
        g2 = g.replicate(2, 4)
        for m in g2.replica_groups()[2]:
            assert cm.frame_time(g2.nodes[m]) == pytest.approx(t / 4)
            assert cm.time(g2.nodes[m]) == pytest.approx(t)  # full per frame

    def test_assignment_load_amortizes_replicas(self):
        g = chain([64, 256, 64])
        cm = CostModel(ROOMY)
        g2 = g.replicate(2, 2)
        a = get_scheduler("lblp", cm).schedule(g2, make_pus(4, 0))
        load = a.load(g2, cm)
        # total amortized load == total unreplicated work per frame
        base_total = sum(cm.time(n) for n in g.nodes.values())
        assert sum(load.values()) == pytest.approx(base_total)

    def test_resolve_graph_on_base_graph_callers(self):
        """Assignment helpers accept the base graph and transparently use
        meta['replicated_graph'] (lblp-r returns mappings over it)."""
        g = chain([64, 1024, 64, 64])
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp-r", cm).schedule(g, make_pus(4, 0))
        assert a.meta["replicas"]               # something replicated
        a.validate(g, cm, check_capacity=False)
        assert sum(a.load(g, cm).values()) > 0
        assert a.resolve_graph(g) is a.meta["replicated_graph"]


class TestReplicatedSimulation:
    def test_replicated_chain_rate_scales(self):
        """One dominant node on k PUs: round-robin replication multiplies
        the saturated processing rate ~k-fold."""
        cm = CostModel(ROOMY)
        g = chain([1024, 64, 64])
        a0 = get_scheduler("lblp", cm).schedule(g, make_pus(3, 0))
        r0 = IMCESimulator(g, cm).run(a0, frames=128)
        g2 = g.replicate(1, 2)
        a2 = get_scheduler("lblp", cm).schedule(g2, make_pus(3, 0))
        r2 = IMCESimulator(g2, cm).run(a2, frames=128)
        # not exactly /2: LBLP's LP-first pass may co-locate a light chain
        # node with one replica (129+18 us here), still a ~1.75x bound cut
        assert r2.bound_interval < r0.bound_interval * 0.65
        assert r2.rate > r0.rate * 1.5

    def test_every_frame_completes_once(self):
        cm = CostModel(ROOMY)
        g = chain([256, 256, 64]).replicate(2, 3)
        a = get_scheduler("lblp", cm).schedule(g, make_pus(4, 0))
        makespan, completions, _, sojourns = IMCESimulator(
            g, cm)._simulate(a, frames=30, in_flight=4)
        assert len(completions) == 30
        assert len(sojourns) == 30
        assert all(s > 0 for s in sojourns)

    def test_replica_work_splits_round_robin(self):
        """Each replica of a 2-group on its own PU gets ~half the frames'
        busy seconds."""
        cm = CostModel(ROOMY)
        g = chain([1024]).replicate(1, 2)
        members = g.replica_groups()[1]
        a = get_scheduler("lblp", cm).schedule(g, make_pus(2, 0))
        assert a.mapping[members[0]] != a.mapping[members[1]]
        r = IMCESimulator(g, cm).run(a, frames=64)
        busys = sorted(r.busy.values())
        assert busys[0] == pytest.approx(busys[1], rel=0.1)

    def test_multi_tenant_replicated_union_runs(self):
        cm = CostModel(ROOMY)
        mt = MultiTenantGraph.union(
            [chain([64, 512], "a"), chain([64, 128], "b")])
        mt_r, a = schedule_replicated(mt, make_pus(4, 0), cm)
        r = MultiTenantSimulator(mt_r, cm).run(a, frames=32)
        assert set(r.tenants) == {"a", "b"}
        for m in r.tenants.values():
            assert m.frames == 32
            assert m.rate > 0


class TestLBLPRScheduler:
    def test_never_worse_bound_than_lblp(self):
        cm = CostModel(ROOMY)
        for seed in (3, 17, 42):
            g = build_random_graph(14, 0.3, seed)
            fleet = make_pus(4, 2)
            b_lblp = max(get_scheduler("lblp", cm)
                         .schedule(g, fleet).load(g, cm).values())
            a = get_scheduler("lblp-r", cm).schedule(g, fleet)
            assert a.meta["bound_interval"] <= b_lblp * (1 + 1e-9), seed

    def test_replicates_dominant_node(self):
        cm = CostModel(ROOMY)
        g = chain([2048, 64, 64, 64])
        a = get_scheduler("lblp-r", cm).schedule(g, make_pus(4, 0))
        assert a.meta["replicas"].get(1, 1) >= 2
        assert a.meta["base_algorithm"] == "lblp"

    def test_budget_zero_is_plain_lblp(self):
        cm = CostModel(ROOMY)
        g = chain([2048, 64, 64])
        fleet = make_pus(4, 0)
        a = LBLPRScheduler(cm, replica_budget=0).schedule(g, fleet)
        assert a.meta["replicas"] == {}
        assert a.mapping == get_scheduler("lblp", cm).schedule(g, fleet).mapping

    def test_rejects_prereplicated_graph(self):
        from repro.core.schedulers import ScheduleError
        cm = CostModel(ROOMY)
        g = chain([256, 64]).replicate(1, 2)
        with pytest.raises(ScheduleError):
            get_scheduler("lblp-r", cm).schedule(g, make_pus(2, 0))

    def test_deterministic(self):
        cm = CostModel(ROOMY)
        g = build_random_graph(16, 0.3, seed=7)
        fleet = make_pus(5, 2)
        a1 = get_scheduler("lblp-r", cm).schedule(g, fleet)
        a2 = get_scheduler("lblp-r", cm).schedule(g, fleet)
        assert a1.mapping == a2.mapping
        assert a1.meta["replicas"] == a2.meta["replicas"]

    def test_validated_rate_never_lower_than_lblp(self):
        """The benchmark acceptance contract, in miniature: with
        measured-rate validation the replicated deployment's processing
        rate is >= plain LBLP's on the same fleet."""
        cm = CostModel()
        from repro.models.cnn.graphs import resnet8_graph
        g = resnet8_graph()
        fleet = make_pus(12, 6)
        base = get_scheduler("lblp", cm).schedule(g, fleet)
        rate0 = IMCESimulator(g, cm).run(base, frames=64).rate
        sched = LBLPRScheduler(cm, validate_rate=64)
        a = sched.schedule(g, fleet)
        g_r = a.meta["replicated_graph"]
        rate_r = IMCESimulator(g_r, cm).run(a, frames=64).rate
        assert rate_r >= rate0 * (1 - 1e-9)
        assert rate_r > rate0 * 1.5             # and the gain is real here


class TestElasticAbsorb:
    def _session_with_replicas(self):
        cm = CostModel(ROOMY)
        g = chain([2048, 64, 64, 64])
        return ElasticSession(g, make_pus(5, 0), algorithm="lblp-r",
                              cost_model=cm)

    def test_replica_pu_failure_absorbed_without_reschedule(self):
        sess = self._session_with_replicas()
        mapping0 = dict(sess.assignment.mapping)
        groups = sess.serving_graph.replica_groups()
        rep_nodes = {m for ms in groups.values() for m in ms}
        victim_pu = next(
            pid for pid in sorted(set(mapping0.values()))
            if all(n in rep_nodes
                   for n, p in mapping0.items() if p == pid))
        ev = sess.fail(victim_pu)
        assert ev.recovery == "replica-absorb"
        dropped = set(mapping0) - set(ev.mapping)
        assert dropped                          # victims removed ...
        assert all(mapping0[n] == victim_pu for n in dropped)
        # ... and every surviving node kept its PU (no re-placement)
        assert all(ev.mapping[n] == mapping0[n] for n in ev.mapping)
        assert ev.rate > 0

    def test_sole_copy_failure_falls_back_to_reschedule(self):
        sess = self._session_with_replicas()
        g = sess.serving_graph
        solo_pu = next(p for n, p in sess.assignment.mapping.items()
                       if g.nodes[n].replica_group is None)
        ev = sess.fail(solo_pu)
        assert ev.recovery == "schedule"
        assert solo_pu not in set(ev.mapping.values())

    def test_unreplicated_session_always_reschedules(self):
        cm = CostModel(ROOMY)
        g = build_random_graph(10, 0.3, seed=5)
        sess = ElasticSession(g, make_pus(3, 2), cost_model=cm)
        ev = sess.fail(2)
        assert ev.recovery == "schedule"


class TestReplicationBenchmark:
    def test_sweep_meets_acceptance_criteria(self):
        """The benchmark contract: lblp-r >= lblp processing rate on every
        sweep cell, with at least one cell genuinely improved."""
        import io
        from contextlib import redirect_stdout

        from benchmarks import replication

        with redirect_stdout(io.StringIO()):
            out = replication.main(frames=16)
        assert out["cells"]
        assert out["cells_geq_base"] == len(out["cells"])
        assert out["cells_improved"] >= 1


# -- property-based widening (skipped cleanly without hypothesis) -----------

class TestProperties:
    @given(seed=st.integers(0, 5000), n_imc=st.integers(2, 5))
    @settings(max_examples=25, deadline=None)
    def test_lblp_r_bound_never_above_lblp(self, seed, n_imc):
        cm = CostModel(ROOMY)
        g = build_random_graph(12, 0.3, seed)
        fleet = make_pus(n_imc, 2)
        b_lblp = max(get_scheduler("lblp", cm)
                     .schedule(g, fleet).load(g, cm).values())
        a = get_scheduler("lblp-r", cm).schedule(g, fleet)
        assert a.meta["bound_interval"] <= b_lblp * (1 + 1e-9)

    @given(seed=st.integers(0, 5000), n_imc=st.integers(3, 6))
    @settings(max_examples=15, deadline=None)
    def test_replication_never_lowers_validated_rate(self, seed, n_imc):
        """lblp-r with measured-rate validation never returns a schedule
        whose processing rate is below plain LBLP's.

        (The *unguarded* form — "blindly k-replicating the heaviest node
        never hurts" — is false: extra replicas can perturb greedy LBLP's
        placement order enough to worsen the bound on adversarial random
        DAGs, which is exactly why lblp-r accepts only improving steps and
        reverts when the gain fails to materialize.)"""
        cm = CostModel(ROOMY)
        g = build_random_graph(10, 0.35, seed, imc_fraction=1.0)
        fleet = make_pus(n_imc, 2)
        frames = 48
        a0 = get_scheduler("lblp", cm).schedule(g, fleet)
        r0 = IMCESimulator(g, cm).run(a0, frames=frames)
        a = LBLPRScheduler(cm, validate_rate=frames).schedule(g, fleet)
        g_r = a.meta["replicated_graph"]
        r = IMCESimulator(g_r, cm).run(a, frames=frames)
        assert r.rate >= r0.rate * (1 - 1e-9)
