"""Pallas kernel validation (interpret mode on CPU; TPU is the target).

Every kernel sweeps shapes/dtypes and asserts allclose against the
pure-jnp oracle in repro.kernels.ref.  Integer paths must be bit-exact
on the accumulator; float epilogues get float tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.kernels import ref
from repro.kernels.conv2d import imc_conv2d
from repro.kernels.flash_attention import flash_attention
from repro.kernels.imc_mvm import imc_mvm
from repro.models import quant


def _rand_int8(key, shape):
    return jax.random.randint(key, shape, -127, 128, dtype=jnp.int8)


class TestIMCMVM:
    @pytest.mark.parametrize("M,K,N", [
        (8, 16, 8), (128, 128, 128), (64, 256, 32), (200, 300, 77),
        (1, 512, 512), (257, 129, 65),
    ])
    def test_matches_oracle(self, M, K, N):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(M * K + N), 3)
        qx = _rand_int8(k1, (M, K))
        qw = _rand_int8(k2, (K, N))
        sx = jnp.float32(0.02)
        sw = jax.random.uniform(k3, (N,), minval=1e-3, maxval=0.2)
        b = jax.random.normal(k3, (N,))
        got = imc_mvm(qx, qw, sx, sw, b, interpret=True)
        want = ref.imc_mvm_ref(qx, qw, sx, sw, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @given(st.integers(1, 64), st.integers(1, 96), st.integers(1, 48),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=12, deadline=None)
    def test_property_random_shapes(self, M, K, N, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        qx = _rand_int8(k1, (M, K))
        qw = _rand_int8(k2, (K, N))
        sw = jnp.full((N,), 0.05, jnp.float32)
        got = imc_mvm(qx, qw, jnp.float32(0.1), sw, None,
                      bm=32, bn=32, bk=32, interpret=True)
        want = ref.imc_mvm_ref(qx, qw, jnp.float32(0.1), sw, None)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_block_shape_sweep(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        qx = _rand_int8(k1, (96, 160))
        qw = _rand_int8(k2, (160, 96))
        sw = jnp.full((96,), 0.01, jnp.float32)
        want = ref.imc_mvm_ref(qx, qw, jnp.float32(0.5), sw, None)
        for bm, bn, bk in [(16, 16, 16), (32, 64, 32), (128, 128, 128),
                           (96, 96, 160)]:
            got = imc_mvm(qx, qw, jnp.float32(0.5), sw, None,
                          bm=bm, bn=bn, bk=bk, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"blocks {bm},{bn},{bk}")

    def test_matches_quant_module(self):
        """Kernel semantics == models.quant integer path (same scales)."""
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (32, 64))
        w = jax.random.normal(jax.random.PRNGKey(8), (64, 16)) * 0.3
        qxt = quant.quantize_act(x)
        qwt = quant.quantize_weight(w, channel_axis=-1)
        got = imc_mvm(qxt.q, qwt.q, qxt.scale, qwt.scale, None,
                      interpret=True)
        want = quant.quantized_matmul(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestConv2D:
    @pytest.mark.parametrize("H,W,Cin,Cout,K,stride", [
        (8, 8, 4, 8, 3, 1),
        (16, 16, 8, 16, 3, 2),
        (32, 32, 3, 16, 3, 1),
        (10, 10, 5, 7, 1, 1),
        (9, 9, 4, 6, 3, 2),
        (12, 12, 8, 130, 5, 1),   # cout > block
    ])
    def test_matches_oracle(self, H, W, Cin, Cout, K, stride):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(H * W + Cout), 3)
        qx = _rand_int8(k1, (2, H, W, Cin))
        qw = _rand_int8(k2, (K, K, Cin, Cout))
        sw = jax.random.uniform(k3, (Cout,), minval=1e-3, maxval=0.1)
        b = jax.random.normal(k3, (Cout,))
        got = imc_conv2d(qx, qw, jnp.float32(0.04), sw, b, stride=stride,
                         interpret=True)
        want = ref.conv2d_ref(qx, qw, jnp.float32(0.04), sw, b,
                              stride=stride)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_resnet8_first_layer_shapes(self):
        """The paper's workload: CIFAR 32x32 stem conv."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        qx = _rand_int8(k1, (4, 32, 32, 3))
        qw = _rand_int8(k2, (3, 3, 3, 16))
        sw = jnp.full((16,), 0.02, jnp.float32)
        got = imc_conv2d(qx, qw, jnp.float32(0.05), sw, None, interpret=True)
        want = ref.conv2d_ref(qx, qw, jnp.float32(0.05), sw, None)
        assert got.shape == (4, 32, 32, 16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,S,hd", [
        (1, 2, 128, 64), (2, 4, 256, 32), (1, 1, 384, 128), (2, 2, 100, 64),
    ])
    def test_causal_matches_oracle(self, B, H, S, hd):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + hd), 3)
        q = jax.random.normal(k1, (B, H, S, hd), jnp.float32)
        k = jax.random.normal(k2, (B, H, S, hd), jnp.float32)
        v = jax.random.normal(k3, (B, H, S, hd), jnp.float32)
        got = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(window), 3)
        q = jax.random.normal(k1, (1, 2, 256, 64), jnp.float32)
        k = jax.random.normal(k2, (1, 2, 256, 64), jnp.float32)
        v = jax.random.normal(k3, (1, 2, 256, 64), jnp.float32)
        got = flash_attention(q, k, v, causal=True, window=window,
                              bq=64, bk=64, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_softcap(self):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
        q = 3.0 * jax.random.normal(k1, (1, 2, 128, 64), jnp.float32)
        k = 3.0 * jax.random.normal(k2, (1, 2, 128, 64), jnp.float32)
        v = jax.random.normal(k3, (1, 2, 128, 64), jnp.float32)
        got = flash_attention(q, k, v, causal=True, softcap=50.0,
                              bq=64, bk=64, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, softcap=50.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_non_causal_with_padding(self):
        """S not a multiple of the block: padded keys must be masked."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
        q = jax.random.normal(k1, (1, 1, 100, 32), jnp.float32)
        k = jax.random.normal(k2, (1, 1, 100, 32), jnp.float32)
        v = jax.random.normal(k3, (1, 1, 100, 32), jnp.float32)
        got = flash_attention(q, k, v, causal=False, bq=64, bk=64,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(k1, (1, 2, 128, 64)).astype(dtype)
        k = jax.random.normal(k2, (1, 2, 128, 64)).astype(dtype)
        v = jax.random.normal(k3, (1, 2, 128, 64)).astype(dtype)
        got = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                              interpret=True)
        want = ref.flash_attention_ref(q.astype(jnp.float32),
                                       k.astype(jnp.float32),
                                       v.astype(jnp.float32), causal=True)
        tol = 2e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)
