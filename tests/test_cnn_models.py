"""CNN workloads: node/param counts vs the paper, executor numerics
parity, INT8 quantization properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.core.graph import OpKind, PUType
from repro.models import quant
from repro.models.cnn import executor, graphs, resnet, yolo
from repro.models.cnn.layers import count_params


class TestPaperCounts:
    def test_resnet8_counts(self):
        g = graphs.resnet8_graph()
        assert len(g) == 14                                  # paper: 14 nodes
        assert g.num_nodes(pu_type=PUType.IMC) == 10         # 10 convolutional
        n = count_params(resnet.init(jax.random.PRNGKey(0), resnet.RESNET8))
        assert 76_000 <= n <= 80_000                         # paper: 78K

    def test_resnet18_counts_and_table1_ids(self):
        g = graphs.resnet18_graph()
        assert len(g) == 30                                  # paper: 30 nodes
        assert g.num_nodes(kind=OpKind.CONV) == 20           # 20 conv layers
        assert g.num_nodes(kind=OpKind.MVM) == 1
        imc = {nid for nid, nd in g.nodes.items() if nd.pu_type == PUType.IMC}
        assert imc == set(graphs.TABLE1_IMC_NODE_IDS)        # Table I ids
        n = count_params(resnet.init(jax.random.PRNGKey(0),
                                     resnet.RESNET18_CIFAR))
        assert 2.7e6 <= n <= 2.9e6                           # paper: 2.8M

    def test_yolov8n_counts(self):
        g = graphs.yolov8n_graph()
        assert len(g) == 233                                 # paper: 233 nodes
        assert g.num_nodes(kind=OpKind.CONV) == 63           # 63 convolutional
        silu = sum(
            1 for n in g.nodes.values()
            if n.kind == OpKind.CONV and any(
                g.nodes[s].kind == OpKind.ACT
                for s in g.successors(n.node_id))
        )
        assert silu == 57                                    # 57 with SiLU
        n = yolo.num_params()
        assert 3.0e6 <= n <= 3.25e6                          # paper: 3.17M

    def test_yolo_parallel_branches(self):
        """The three detection scales are parallel branches (paper: '3
        parallel main branches')."""
        g = graphs.yolov8n_graph()
        heads = [nid for nid, n in g.nodes.items()
                 if n.name.startswith("head.cv3") and n.name.endswith(".2")]
        assert len(heads) == 3
        for i in range(3):
            for j in range(i + 1, 3):
                assert g.is_parallel(heads[i], heads[j])


class TestExecutorParity:
    @pytest.mark.parametrize("cfg", [resnet.RESNET8, resnet.RESNET18_CIFAR],
                             ids=["resnet8", "resnet18"])
    def test_graph_execution_matches_reference(self, cfg):
        key = jax.random.PRNGKey(0)
        params = resnet.init(key, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
        ref = resnet.forward(params, x, cfg)
        g = graphs.build_resnet_graph(cfg)
        got = executor.execute(g, params, x, mode="float")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_int8_execution_close_to_float(self):
        cfg = resnet.RESNET8
        params = resnet.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
        g = graphs.build_resnet_graph(cfg)
        f32 = executor.execute(g, params, x, mode="float")
        i8 = executor.execute(g, params, x, mode="int8")
        assert jnp.isfinite(i8).all()
        # top-1 agreement on most samples + bounded relative error
        agree = jnp.mean(
            (jnp.argmax(f32, -1) == jnp.argmax(i8, -1)).astype(jnp.float32))
        assert agree >= 0.75
        rel = jnp.linalg.norm(i8 - f32) / jnp.linalg.norm(f32)
        assert rel < 0.25

    def test_yolo_forward_shapes(self):
        params = yolo.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
        out = yolo.forward(params, x)
        assert out.shape == (1, 8 * 8 + 4 * 4 + 2 * 2, 4 + yolo.NC)
        assert jnp.isfinite(out).all()
        raw = yolo.forward(params, x, decode=False)
        assert [r.shape for r in raw] == [
            (1, 8, 8, 144), (1, 4, 4, 144), (1, 2, 2, 144)]


class TestQuant:
    @given(st.integers(0, 1000), st.integers(1, 6), st.integers(1, 64))
    @settings(max_examples=30, deadline=None)
    def test_weight_roundtrip_error_bound(self, seed, rows, cols):
        key = jax.random.PRNGKey(seed)
        w = jax.random.normal(key, (rows * 4, cols)) * \
            jax.random.uniform(key, (1, cols), minval=0.1, maxval=10.0)
        qt = quant.quantize_weight(w, channel_axis=-1)
        back = quant.dequantize(qt, channel_axis=-1)
        # per-channel error bounded by scale/2 per element
        err = jnp.abs(back - w)
        bound = qt.scale[None, :] * 0.5 + 1e-7
        assert bool(jnp.all(err <= bound))

    @given(st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_int8_matmul_exactness(self, seed):
        """Integer accumulate is exact: matches float64 computation of the
        same quantized integers."""
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        qx = jax.random.randint(k1, (8, 32), -127, 128, dtype=jnp.int32)
        qw = jax.random.randint(k2, (32, 16), -127, 128, dtype=jnp.int32)
        acc = quant.int8_matmul_acc(qx.astype(jnp.int8), qw.astype(jnp.int8))
        ref = np.asarray(qx, np.int64) @ np.asarray(qw, np.int64)
        np.testing.assert_array_equal(np.asarray(acc, np.int64), ref)

    def test_quantized_conv_close(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (2, 16, 16, 8))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 16)) * 0.2
        b = jnp.zeros((16,))
        ref = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        got = quant.quantized_conv2d(x, w, b)
        rel = jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref)
        assert rel < 0.05

    def test_aimc_noise_hook(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        clean = quant.quantized_matmul(x, w)
        noisy = quant.quantized_matmul(x, w, noise_std=5.0,
                                       key=jax.random.PRNGKey(2))
        assert not jnp.allclose(clean, noisy)

    def test_calibration_scales_cover_layers(self):
        cfg = resnet.RESNET8
        params = resnet.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32, 3))
        scales = quant.calibrate_resnet(params, x, cfg)
        g = graphs.build_resnet_graph(cfg)
        conv_names = {n.name for n in g.nodes.values()
                      if n.kind in (OpKind.CONV, OpKind.MVM)}
        assert conv_names <= set(scales)
        assert all(s > 0 for s in scales.values())
