"""Serving control plane: SLO evaluation helpers, trace format, tenant
weight priorities, the transfer-aware replication gain model, elastic
tenant churn (including the stale-cache regression), admission /
reclaim / eviction behaviour and decision-log determinism.
"""

import math

import pytest

from repro.core.cost import CostModel, HardwareProfile, make_pus
from repro.core.elastic import ElasticSession
from repro.core.graph import Graph, GraphError, MultiTenantGraph, OpKind
from repro.core.schedulers import get_scheduler
from repro.core.schedulers.lblp_r import (LBLPRScheduler, estimated_gain,
                                          measured_rate)
from repro.core.serving import (SLO, ServingControlPlane, TraceEvent,
                                aggregate_goodput, dump_trace, load_trace)
from repro.core.simulator import MultiTenantSimulator, TenantMetrics

from helpers import build_random_graph

ROOMY = HardwareProfile(name="roomy", pu_weight_capacity=1e12)


def union_of(seeds, n_nodes=8):
    return MultiTenantGraph.union(
        [build_random_graph(n_nodes, 0.3, s) for s in seeds],
        names=[f"t{s}" for s in seeds])


def metrics(rate, latency):
    return TenantMetrics(tenant="x", frames=10, rate=rate, interval=1 / rate,
                         latency=latency, bound_interval=0.0, busy={},
                         utilization_share=0.5)


class TestSLOHelpers:
    def test_headroom_signs_and_binding_dimension(self):
        m = metrics(rate=100.0, latency=0.010)
        assert m.slo_headroom() == math.inf              # nothing promised
        assert m.slo_headroom(min_rate=50.0) == pytest.approx(1.0)
        assert m.slo_headroom(min_rate=200.0) == pytest.approx(-0.5)
        assert m.slo_headroom(max_latency=0.020) == pytest.approx(0.5)
        assert m.slo_headroom(max_latency=0.005) == pytest.approx(-1.0)
        # min over dimensions: latency binds here
        assert m.slo_headroom(min_rate=50.0,
                              max_latency=0.005) == pytest.approx(-1.0)
        assert m.meets_slo(min_rate=50.0, max_latency=0.020)
        assert not m.meets_slo(min_rate=200.0)

    def test_simresult_slo_map(self):
        mt = union_of([1, 2])
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(3, 2))
        r = MultiTenantSimulator(mt, cm).run(a, frames=24)
        slos = {t: (r.tenants[t].rate * 0.5, None) for t in mt.tenants}
        heads = r.slo_headroom(slos)
        assert set(heads) == set(mt.tenants)
        assert all(h == pytest.approx(1.0) for h in heads.values())
        assert r.meets_slos(slos)
        assert not r.meets_slos(
            {t: (r.tenants[t].rate * 2.0, None) for t in mt.tenants})


class TestTraceFormat:
    def test_round_trip(self):
        trace = [
            TraceEvent("arrive", tenant="a", model="m",
                       slo=SLO(min_rate=10.0, max_latency=0.5), weight=2.0),
            TraceEvent("load", tenant="a", weight=0.5),
            TraceEvent("fail", pu_id=3),
            TraceEvent("join", pu_id=3, pu_type="imc", speed=1.5),
            TraceEvent("depart", tenant="a"),
        ]
        assert load_trace(dump_trace(trace)) == trace

    def test_partial_slo(self):
        assert SLO.from_dict({"min_rate": 5.0}) == SLO(min_rate=5.0)
        assert SLO.from_dict(None) == SLO()
        assert SLO(max_latency=0.1).to_dict() == {"max_latency": 0.1}


class TestTenantWeights:
    def test_weighted_tenant_gets_larger_share(self):
        """Two copies of one model on a *contended* fleet: the weight-4
        copy must out-rate the weight-1 copy roughly 4:1 under weighted
        fair queueing.  Measured on the periodic engine, whose
        steady-state extrapolation reports the sustained contended
        regime — the exact engine's finite-budget drain tail lets the
        de-prioritized tenant finish uncontended and mask the share.
        (On a roomy fleet both streams are pipeline-limited instead and
        weights have nothing to arbitrate.)"""
        from repro.core import make_simulator
        g = build_random_graph(10, 0.3, seed=5)
        mt = MultiTenantGraph.union([g, g], names=["lo", "hi"])
        mt.set_tenant_weight("hi", 4.0)
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(1, 1))
        r = make_simulator(mt, cm, engine="periodic").run(a, frames=96)
        assert r.tenants["hi"].rate > r.tenants["lo"].rate * 2.5

    def test_weight_change_not_masked_by_run_memo(self):
        """Re-weighting without any structural mutation must not hit the
        pre-weight run memo (the regression the weighted memo key guards
        against)."""
        g = build_random_graph(10, 0.3, seed=6)
        mt = MultiTenantGraph.union([g, g], names=["a", "b"])
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(3, 2))
        sim = MultiTenantSimulator(mt, cm)
        r1 = sim.run(a, frames=48)
        mt.set_tenant_weight("a", 4.0)
        r2 = sim.run(a, frames=48)
        assert r2.tenants["a"].rate > r1.tenants["a"].rate

    def test_default_weights_reduce_to_unweighted(self):
        mt = union_of([7, 8])
        cm = CostModel(ROOMY)
        fleet = make_pus(3, 2)
        m1 = get_scheduler("lblp-mt", cm).schedule(mt, fleet)
        for t in mt.tenants:
            mt.set_tenant_weight(t, 1.0)
        m2 = get_scheduler("lblp-mt", cm).schedule(mt, fleet)
        assert m1.mapping == m2.mapping
        assert m1.meta["tenant_weights"] == {t: 1.0 for t in mt.tenants}

    def test_weights_survive_copy_and_json(self):
        mt = union_of([9, 10])
        mt.set_tenant_weight(mt.tenants[0], 3.0)
        assert mt.copy().tenant_weight(mt.tenants[0]) == 3.0
        rt = MultiTenantGraph.from_json(mt.to_json())
        assert rt.tenant_weight(mt.tenants[0]) == 3.0
        assert rt.tenant_weight(mt.tenants[1]) == 1.0

    def test_weight_validation(self):
        mt = union_of([11])
        with pytest.raises(GraphError):
            mt.set_tenant_weight("nope", 2.0)
        with pytest.raises(GraphError):
            mt.set_tenant_weight(mt.tenants[0], 0.0)


def transfer_heavy_graph():
    """A bottleneck conv whose neighbours ship huge activations: the
    transfer penalty dwarfs the per-frame compute freed by widening, so
    the gain model must prune it."""
    g = Graph("xfer-heavy")
    src = g.add("in", OpKind.INPUT)
    a = g.add("producer", OpKind.CONV, deps=[src.node_id], flops=1e6,
              weight_bytes=1e3, out_bytes=80e6, out_elems=1e3,
              meta=dict(cin_kk=27, cout=16, n_vectors=16))
    b = g.add("tiny-bottleneck", OpKind.CONV, deps=[a.node_id], flops=1e6,
              weight_bytes=1e3, out_bytes=80e6, out_elems=1e3,
              meta=dict(cin_kk=27, cout=16, n_vectors=16))
    g.add("out", OpKind.OUTPUT, deps=[b.node_id])
    return g


class TestEstimatedGain:
    def test_positive_for_heavy_compute_bottleneck(self):
        g = build_random_graph(10, 0.3, seed=20)
        cm = CostModel(ROOMY)
        fleet = make_pus(4, 2)
        a = get_scheduler("lblp", cm).schedule(g, fleet)
        load = a.load(g, cm)
        hot = max(load, key=lambda p: load[p])
        node = max((g.nodes[n] for n, p in a.mapping.items()
                    if p == hot and not g.nodes[n].is_free()),
                   key=lambda n: cm.time(n))
        assert estimated_gain(g, node, 2, cm, fleet, load) > 0.0

    def test_negative_for_transfer_heavy_node(self):
        g = transfer_heavy_graph()
        cm = CostModel(ROOMY)
        fleet = make_pus(4, 2)
        a = get_scheduler("lblp", cm).schedule(g, fleet)
        load = a.load(g, cm)
        assert estimated_gain(g, g.nodes[3], 2, cm, fleet, load) <= 0.0

    def test_pruning_counter_and_measured_rate(self):
        """The gain model drops probes on transfer-heavy candidates (the
        counter proves it), and what it drops is exactly the replication
        whose analytic bound gain the added transfers would eat: the
        unpruned search accepts it and *loses* measured rate."""
        cm = CostModel(ROOMY)
        fleet = make_pus(4, 2)
        g = transfer_heavy_graph()
        a_on = LBLPRScheduler(cm, replica_budget=4).schedule(g, fleet)
        a_off = LBLPRScheduler(cm, replica_budget=4,
                               gain_model=False).schedule(g, fleet)
        assert a_on.meta["probes_pruned"] > 0
        assert a_off.meta["probes_pruned"] == 0
        assert a_on.meta["extra_replicas"] == 0   # all candidates pruned
        assert a_off.meta["extra_replicas"] > 0   # bound-only search bites
        r_on = measured_rate(a_on.meta["replicated_graph"], a_on, cm, 64)
        r_off = measured_rate(a_off.meta["replicated_graph"], a_off, cm, 64)
        assert r_on >= r_off
        # pruned search still returns an executable schedule
        a_on.validate(g, cm, check_capacity=False)

    def test_rejects_unwidened_group(self):
        g = build_random_graph(6, 0.3, seed=21)
        cm = CostModel(ROOMY)
        fleet = make_pus(2, 1)
        load = get_scheduler("lblp", cm).schedule(g, fleet).load(g, cm)
        with pytest.raises(Exception):
            estimated_gain(g, g.nodes[1], 1, cm, fleet, load)


class TestElasticTenantChurn:
    def _session(self, seeds, fleet=(4, 2)):
        mt = union_of(seeds)
        return mt, ElasticSession(mt, make_pus(*fleet), cost_model=CostModel(ROOMY))

    def test_add_tenant_re_coschedules(self):
        mt, sess = self._session([30])
        g2 = build_random_graph(9, 0.3, seed=31)
        ev = sess.add_tenant(g2, "late")
        assert ev.recovery == "tenant-add" and ev.tenant == "late"
        assert set(ev.tenant_rates) == {"t30", "late"}
        assert all(r > 0 for r in ev.tenant_rates.values())
        assert set(sess.assignment.mapping) == set(mt.nodes)

    def test_churn_invalidates_union_sim_caches(self):
        """Regression: the session's id-keyed simulator cache used to
        survive an in-place union mutation, handing back a compiled
        context (and measured_rate/run memos) for the *previous* tenant
        set."""
        mt, sess = self._session([32])
        sim_before = sess._sim_for(sess.serving_graph)
        n_before = sim_before._ctx.n
        sess.add_tenant(build_random_graph(9, 0.3, seed=33), "late")
        sim_after = sess._sim_for(sess.serving_graph)
        assert sim_after is not sim_before
        assert sim_after._ctx.n == len(mt.nodes) > n_before
        # the fresh context simulates the union that exists now
        assert sim_after._ctx.graph is sess.serving_graph

    def test_stale_measured_rate_memo_across_churn(self):
        """measured_rate memos live on the compiled context; after churn
        the same (mapping, fleet) key must not resurrect the pre-churn
        figure."""
        mt, sess = self._session([34])
        cm = sess.cm
        a1 = sess.assignment
        r1 = measured_rate(mt, a1, cm, 32, sim=sess._sim_for(mt))
        sess.add_tenant(build_random_graph(9, 0.3, seed=35), "late")
        a2 = sess.assignment
        r2 = measured_rate(mt, a2, cm, 32, sim=sess._sim_for(mt))
        # aggregate rate over two tenants of a contended fleet differs
        # from the solo figure; a stale memo would return r1 verbatim
        assert r2 != r1
        # and the memo itself lives on a fresh context
        assert sess._sim_for(mt)._ctx.n == len(mt.nodes)

    def test_remove_tenant_and_empty_union(self):
        mt, sess = self._session([36, 37])
        t36_nodes = set(mt.tenant_nodes("t36"))
        ev = sess.remove_tenant("t36")
        assert ev.recovery == "tenant-remove"
        assert set(ev.tenant_rates) == {"t37"}
        assert not t36_nodes & set(mt.nodes)
        ev = sess.remove_tenant("t37")
        assert ev.rate == 0.0 and ev.tenant_rates == {}
        # a drained session can grow again
        ev = sess.add_tenant(build_random_graph(6, 0.3, seed=38), "back")
        assert set(ev.tenant_rates) == {"back"}

    def test_remove_tenant_drops_its_replicas(self):
        mt, sess = self._session([39, 40])
        base = mt.tenant_nodes("t39")[0]
        while mt.nodes[base].is_free():
            base += 1
        sess.set_replicas({base: 2})
        assert sess.replica_counts() == {base: 2}
        sess.remove_tenant("t39")
        assert sess.replica_counts() == {}
        assert set(sess.assignment.mapping) == set(mt.nodes)

    def test_reweight_changes_share_without_structural_churn(self):
        mt, sess = self._session([41, 42])
        ctxs_before = mt.__dict__.get("_sim_contexts")
        r_before = dict(sess.history[-1].tenant_rates)
        ev = sess.reweight("t41", 4.0)
        assert ev.recovery == "reweight"
        assert ev.tenant_rates["t41"] > r_before["t41"]
        # weights are policy, not structure: compiled contexts survive
        assert mt.__dict__.get("_sim_contexts") is ctxs_before

    def test_churn_needs_multitenant_graph(self):
        g = build_random_graph(6, 0.3, seed=43)
        sess = ElasticSession(g, make_pus(2, 1), cost_model=CostModel(ROOMY))
        with pytest.raises(TypeError):
            sess.add_tenant(build_random_graph(4, 0.3, seed=44))


def small_models():
    return {"m1": build_random_graph(8, 0.3, 100),
            "m2": build_random_graph(10, 0.3, 101)}


def demo_trace(tight=False):
    frac = 5.0 if tight else 0.15
    return [
        TraceEvent("arrive", tenant="a", model="m1",
                   slo=SLO(min_rate=900.0 * 0.3)),
        TraceEvent("arrive", tenant="b", model="m2",
                   slo=SLO(min_rate=900.0 * frac)),
        TraceEvent("fail", pu_id=2),
        TraceEvent("load", tenant="a", weight=2.0),
        TraceEvent("depart", tenant="b"),
        TraceEvent("join", pu_id=2, pu_type="imc"),
    ]


class TestControlPlane:
    def _plane(self, engine="periodic", **kw):
        return ServingControlPlane(make_pus(4, 2), small_models(),
                                   cost_model=CostModel(ROOMY),
                                   engine=engine, frames=32, **kw)

    def test_admit_and_reject(self):
        plane = self._plane()
        plane.play(demo_trace(tight=True))
        acts = {(d.action, d.tenant) for d in plane.decisions}
        assert ("admit", "a") in acts
        assert ("reject", "b") in acts
        assert plane.reports["a"].satisfied()
        assert plane.reports["b"].rejected_index is not None
        assert not plane.reports["b"].samples
        # the rejected tenant's depart replays as a recorded no-op
        assert ("noop", "b") in acts

    def test_admitted_slos_hold_throughout(self):
        plane = self._plane()
        plane.play(demo_trace())
        for t, rep in plane.reports.items():
            if rep.admitted_index is not None and rep.evicted_index is None:
                assert rep.satisfied(), (t, rep.violations)

    def test_admit_all_baseline_shows_violations(self):
        models = small_models()
        cm = CostModel(ROOMY)
        # each arrival demands ~45% of the model's solo rate: two fit,
        # four cannot
        from repro.core import make_simulator
        g = models["m1"]
        fleet = make_pus(2, 1)
        solo = make_simulator(g, cm, engine="periodic").run(
            get_scheduler("lblp", cm).schedule(g, fleet), frames=32).rate
        trace = [
            TraceEvent("arrive", tenant=f"t{i}", model="m1",
                       slo=SLO(min_rate=solo * 0.45))
            for i in range(4)
        ]
        aware = ServingControlPlane(make_pus(2, 1), models,
                                    cost_model=cm, frames=32)
        aware.play(trace)
        greedy = ServingControlPlane(make_pus(2, 1), models,
                                     cost_model=cm, frames=32,
                                     admission=False, autoscale=False)
        greedy.play(trace)
        admitted = [r for r in aware.reports.values()
                    if r.admitted_index is not None]
        assert all(r.satisfied() for r in admitted)
        assert len(admitted) < 4          # something was turned away
        # admit-all admits everyone and breaks promises
        assert all(r.admitted_index is not None
                   for r in greedy.reports.values())
        assert any(r.violations for r in greedy.reports.values())
        _, g_aware = aggregate_goodput(aware.reports, aware.n_events)
        _, g_greedy = aggregate_goodput(greedy.reports, greedy.n_events)
        assert g_aware >= g_greedy * (1 - 1e-9)

    def test_reclaim_makes_room(self):
        """Replicas spent on throughput are reclaimed when the capacity
        is needed to honor a new promise: probe-with-replicas fails,
        probe-unreplicated passes => reclaim decision + admission.  The
        probes are stubbed so the branch fires deterministically."""
        from dataclasses import replace
        plane = self._plane(autoscale=False)
        plane.step(TraceEvent("arrive", tenant="a", model="m1",
                              slo=SLO(min_rate=100.0)))
        base = next(n for n in sorted(plane.union.nodes)
                    if not plane.union.nodes[n].is_free())
        plane.replicas = {base: 2}
        plane.session.set_replicas(plane.replicas)
        assert plane.session.replica_counts() == {base: 2}

        real_result = plane._result()

        def fake_probe(g, tenant, weight, counts, cand=None):
            # the newcomer starves while the replicas hold the capacity
            rate = 50.0 if counts else 200.0
            return replace(
                real_result,
                tenants={**real_result.tenants,
                         tenant: metrics(rate=rate, latency=0.001)})

        plane._probe_arrival = fake_probe
        plane.step(TraceEvent("arrive", tenant="b", model="m2",
                              slo=SLO(min_rate=100.0)))
        acts = [(d.action, d.tenant) for d in plane.decisions]
        assert ("reclaim", None) in acts
        assert ("admit", "b") in acts
        assert plane.replicas == {}
        assert plane.session.replica_counts() == {}
        assert plane.reports["b"].admitted_index == 1

    def test_eviction_repair_after_capacity_loss(self):
        """Failing PUs under an admitted population must shed tenants
        rather than sample violated SLOs."""
        models = small_models()
        plane = ServingControlPlane(make_pus(3, 2), models,
                                    cost_model=CostModel(ROOMY), frames=32)
        plane.step(TraceEvent("arrive", tenant="a", model="m1",
                              slo=SLO(min_rate=400.0), weight=2.0))
        plane.step(TraceEvent("arrive", tenant="b", model="m1",
                              slo=SLO(min_rate=400.0), weight=0.5))
        plane.step(TraceEvent("fail", pu_id=1))
        plane.step(TraceEvent("fail", pu_id=2))
        for rep in plane.reports.values():
            if rep.admitted_index is not None:
                assert rep.satisfied(), rep
        evicted = [t for t, r in plane.reports.items()
                   if r.evicted_index is not None]
        if evicted:
            # lightest weight goes first
            assert evicted[0] == "b"

    def test_audit_json_is_strict_json(self):
        """A tenant with no promised dimension has infinite headroom;
        the audit artifact must still be spec-compliant JSON (null, not
        the Infinity token)."""
        import json
        plane = self._plane()
        plane.step(TraceEvent("arrive", tenant="free", model="m1"))
        text = plane.audit_json()
        assert "Infinity" not in text

        def reject_constants(name):
            raise AssertionError(f"non-standard JSON constant {name}")

        json.loads(text, parse_constant=reject_constants)

    def test_join_of_live_pu_rejected(self):
        """join of an already-live pu_id must raise (duplicate specs
        would double-book one physical unit in every pu_id-keyed
        accounting structure), mirroring fail() on an unknown PU."""
        plane = self._plane()
        plane.step(TraceEvent("arrive", tenant="a", model="m1",
                              slo=SLO(min_rate=1.0)))
        with pytest.raises(KeyError):
            plane.step(TraceEvent("join", pu_id=1, pu_type="imc"))

    def test_duplicate_tenant_name_rejected(self):
        plane = self._plane()
        plane.step(TraceEvent("arrive", tenant="a", model="m1",
                              slo=SLO(min_rate=1.0)))
        with pytest.raises(GraphError):
            plane.step(TraceEvent("arrive", tenant="a", model="m1",
                                  slo=SLO(min_rate=1.0)))

    def test_goodput_counts_only_met_slos(self):
        from repro.core.serving import SLOReport
        reports = {
            "ok": SLOReport("ok", SLO(min_rate=1.0), 1.0, admitted_index=0,
                            samples=[(0, 10.0, 0.0, 1.0), (1, 10.0, 0.0, 0.5)]),
            "bad": SLOReport("bad", SLO(min_rate=1.0), 1.0, admitted_index=0,
                             samples=[(0, 8.0, 0.0, -0.1), (1, 8.0, 0.0, 0.2)]),
        }
        per_tick, mean = aggregate_goodput(reports, 2)
        assert per_tick == [10.0, 18.0]
        assert mean == pytest.approx(14.0)
        assert reports["bad"].violations == [(0, 0)]
        assert not reports["bad"].satisfied()


class TestAdmissionDeterminism:
    @pytest.mark.parametrize("engine", ["exact", "periodic"])
    def test_bit_identical_audit_per_engine(self, engine):
        """Same trace + fleet + engine => bit-identical decision log and
        SLO reports (the audit artifact is canonical JSON, so string
        equality is bitwise equality of every float in it)."""
        models = small_models()
        trace = demo_trace()

        def audit():
            plane = ServingControlPlane(
                make_pus(4, 2), models, cost_model=CostModel(ROOMY),
                engine=engine, frames=32)
            plane.play(trace)
            return plane.audit_json()

        first = audit()
        assert audit() == first
        assert '"decisions"' in first and '"reports"' in first
