"""Shared test utilities: random deployment-graph strategies.

``hypothesis`` is an optional test dependency (``pip install .[test]``).
When it is absent the property tests must not break collection, so this
module exports drop-in ``given`` / ``settings`` / ``st`` shims: the
decorated tests are collected normally and skip with a clear reason.
Deterministic tests built on :func:`build_random_graph` run either way.
"""

from __future__ import annotations

import random

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # degrade gracefully: collect, then skip
    HAVE_HYPOTHESIS = False
    SKIP_REASON = ("hypothesis is not installed — property test skipped "
                   "(install the [test] extra: pip install .[test])")

    class _StubStrategy:
        """Placeholder for strategy objects built at import time; supports
        arbitrary chaining (``st.tuples(...).map(...)``) but never runs."""

        def __call__(self, *a, **kw):
            return self

        def __getattr__(self, name):
            return self

    st = _StubStrategy()  # type: ignore[assignment]

    def given(*_args, **_kwargs):
        def deco(fn):
            # signature (*a, **kw) on purpose: pytest must not treat the
            # hypothesis-bound parameters as fixtures.
            def skipper(*a, **kw):
                pytest.skip(SKIP_REASON)

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco


from repro.core.graph import Graph, OpKind

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS",
           "build_random_graph", "random_graph_st"]

IMC_OPS = [OpKind.CONV, OpKind.MVM]
DPU_OPS = [OpKind.ADD, OpKind.POOL_MAX, OpKind.POOL_AVG, OpKind.CONCAT,
           OpKind.RESHAPE, OpKind.SOFTMAX]


def build_random_graph(n_nodes: int, edge_density: float, seed: int,
                       imc_fraction: float = 0.6) -> Graph:
    """Random connected-ish DAG with mixed IMC/DPU nodes.

    Edges only go from lower to higher ids (guarantees acyclicity); every
    non-source node gets at least one predecessor so the graph is a single
    weakly-connected component rooted at node 1.
    """
    rng = random.Random(seed)
    g = Graph(f"rand-{seed}")
    for i in range(n_nodes):
        if rng.random() < imc_fraction:
            kind = rng.choice(IMC_OPS)
            weight = rng.uniform(1e3, 300e3)
            meta = {
                "cin_kk": rng.choice([27, 64, 144, 288, 576, 1152]),
                "cout": rng.choice([16, 32, 64, 128, 256]),
                "n_vectors": rng.choice([1, 64, 256, 1024, 4096]),
            }
        else:
            kind = rng.choice(DPU_OPS)
            weight = 0.0
            meta = {}
        g.add(
            f"n{i+1}", kind,
            flops=rng.uniform(1e5, 5e7),
            weight_bytes=weight,
            out_bytes=rng.uniform(1e3, 64e3),
            out_elems=rng.uniform(1e3, 64e3),
            meta=meta,
        )
    ids = sorted(g.nodes)
    for j_idx, j in enumerate(ids[1:], start=1):
        preds = [i for i in ids[:j_idx] if rng.random() < edge_density]
        if not preds:
            preds = [rng.choice(ids[:j_idx])]
        for p in preds:
            g.add_edge(p, j)
    g.validate()
    return g


random_graph_st = st.builds(
    build_random_graph,
    n_nodes=st.integers(min_value=2, max_value=24),
    edge_density=st.floats(min_value=0.05, max_value=0.5),
    seed=st.integers(min_value=0, max_value=10_000),
    imc_fraction=st.floats(min_value=0.2, max_value=0.9),
)
