"""Shared test utilities: random deployment-graph strategies."""

from __future__ import annotations

import random
from typing import List, Tuple

from hypothesis import strategies as st

from repro.core.graph import Graph, OpKind

IMC_OPS = [OpKind.CONV, OpKind.MVM]
DPU_OPS = [OpKind.ADD, OpKind.POOL_MAX, OpKind.POOL_AVG, OpKind.CONCAT,
           OpKind.RESHAPE, OpKind.SOFTMAX]


def build_random_graph(n_nodes: int, edge_density: float, seed: int,
                       imc_fraction: float = 0.6) -> Graph:
    """Random connected-ish DAG with mixed IMC/DPU nodes.

    Edges only go from lower to higher ids (guarantees acyclicity); every
    non-source node gets at least one predecessor so the graph is a single
    weakly-connected component rooted at node 1.
    """
    rng = random.Random(seed)
    g = Graph(f"rand-{seed}")
    for i in range(n_nodes):
        if rng.random() < imc_fraction:
            kind = rng.choice(IMC_OPS)
            weight = rng.uniform(1e3, 300e3)
            meta = {
                "cin_kk": rng.choice([27, 64, 144, 288, 576, 1152]),
                "cout": rng.choice([16, 32, 64, 128, 256]),
                "n_vectors": rng.choice([1, 64, 256, 1024, 4096]),
            }
        else:
            kind = rng.choice(DPU_OPS)
            weight = 0.0
            meta = {}
        g.add(
            f"n{i+1}", kind,
            flops=rng.uniform(1e5, 5e7),
            weight_bytes=weight,
            out_bytes=rng.uniform(1e3, 64e3),
            out_elems=rng.uniform(1e3, 64e3),
            meta=meta,
        )
    ids = sorted(g.nodes)
    for j_idx, j in enumerate(ids[1:], start=1):
        preds = [i for i in ids[:j_idx] if rng.random() < edge_density]
        if not preds:
            preds = [rng.choice(ids[:j_idx])]
        for p in preds:
            g.add_edge(p, j)
    g.validate()
    return g


random_graph_st = st.builds(
    build_random_graph,
    n_nodes=st.integers(min_value=2, max_value=24),
    edge_density=st.floats(min_value=0.05, max_value=0.5),
    seed=st.integers(min_value=0, max_value=10_000),
    imc_fraction=st.floats(min_value=0.2, max_value=0.9),
)
