"""Graph IR: topo order, longest path, parallelism — unit + property tests
(the longest-path oracle is networkx)."""

import pytest

try:
    import networkx as nx
except ModuleNotFoundError:  # minimal-deps leg: oracle tests skip below
    nx = None

from repro.core.cost import CostModel
from repro.core.graph import Graph, GraphError, Node, OpKind, PUType

from helpers import build_random_graph, given, random_graph_st, settings

requires_nx = pytest.mark.skipif(nx is None, reason="networkx not installed")


def to_networkx(g: Graph, cm: CostModel) -> "nx.DiGraph":
    ng = nx.DiGraph()
    for nid, node in g.nodes.items():
        t = cm.time(node) if not node.is_free() else 0.0
        ng.add_node(nid, t=t)
    for s, d in g.edges():
        ng.add_edge(s, d)
    return ng


class TestBasics:
    def test_duplicate_id_rejected(self):
        g = Graph()
        g.add_node(Node(1, "a", OpKind.CONV))
        with pytest.raises(GraphError):
            g.add_node(Node(1, "b", OpKind.ADD))

    def test_cycle_detected(self):
        g = Graph()
        g.add_node(Node(1, "a", OpKind.CONV))
        g.add_node(Node(2, "b", OpKind.CONV))
        g.add_edge(1, 2)
        g.add_edge(2, 1)
        with pytest.raises(GraphError):
            g.topo_order()

    def test_default_pu_types(self):
        assert Node(1, "c", OpKind.CONV).pu_type is PUType.IMC
        assert Node(2, "m", OpKind.MVM).pu_type is PUType.IMC
        assert Node(3, "a", OpKind.ADD).pu_type is PUType.DPU
        assert Node(4, "p", OpKind.POOL_MAX).pu_type is PUType.DPU

    def test_json_roundtrip(self):
        g = build_random_graph(12, 0.3, seed=7)
        g2 = Graph.from_json(g.to_json())
        assert sorted(g2.nodes) == sorted(g.nodes)
        assert sorted(g2.edges()) == sorted(g.edges())
        for nid in g.nodes:
            assert g2.nodes[nid].kind == g.nodes[nid].kind
            assert g2.nodes[nid].weight_bytes == g.nodes[nid].weight_bytes


class TestProperties:
    @given(random_graph_st)
    @settings(max_examples=60, deadline=None)
    def test_topo_order_respects_edges(self, g: Graph):
        order = g.topo_order()
        pos = {n: i for i, n in enumerate(order)}
        assert len(order) == len(g.nodes)
        for s, d in g.edges():
            assert pos[s] < pos[d]

    @requires_nx
    @given(random_graph_st)
    @settings(max_examples=40, deadline=None)
    def test_longest_path_matches_networkx(self, g: Graph):
        cm = CostModel()
        lp = g.longest_path(lambda n: cm.time(n))
        # path must be a real path
        for a, b in zip(lp, lp[1:]):
            assert b in g.successors(a)
        my_len = sum(cm.time(g.nodes[n]) for n in lp if not g.nodes[n].is_free())

        # networkx oracle: put node weight on incoming edges + source handling
        ng = to_networkx(g, cm)
        best = 0.0
        topo = list(nx.topological_sort(ng))
        dist = {}
        for n in topo:
            t = ng.nodes[n]["t"]
            dist[n] = t + max((dist[p] for p in ng.predecessors(n)), default=0.0)
            best = max(best, dist[n])
        assert my_len == pytest.approx(best, rel=1e-9)

    @requires_nx
    @given(random_graph_st)
    @settings(max_examples=40, deadline=None)
    def test_is_parallel_matches_reachability(self, g: Graph):
        ng = nx.DiGraph(list(g.edges()))
        ng.add_nodes_from(g.nodes)
        ids = sorted(g.nodes)
        import itertools
        reach = {n: nx.descendants(ng, n) for n in ids}
        for a, b in itertools.combinations(ids[:12], 2):
            expected = (b not in reach[a]) and (a not in reach[b])
            assert g.is_parallel(a, b) == expected
            assert g.is_parallel(b, a) == expected

    @given(random_graph_st)
    @settings(max_examples=30, deadline=None)
    def test_levels_monotone_on_edges(self, g: Graph):
        lvl = g.depth_levels()
        for s, d in g.edges():
            assert lvl[d] > lvl[s]
