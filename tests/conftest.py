"""Collection-time dependency gating.

The scheduling core (graph/schedulers/simulator/elastic) is pure stdlib
and must stay testable with no optional dependencies installed (the CI
minimal-deps leg).  Modules whose subject *is* an optional dependency
(jax kernels, LM tier, CNN executors) are skipped wholesale when jax is
missing; per-test shims (``tests/helpers.py`` for hypothesis,
``requires_nx`` in test_graph.py for networkx) handle the finer grain.
"""

import importlib.util


def _missing(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is None
    except ModuleNotFoundError:  # broken/blocked distribution counts as absent
        return True


collect_ignore = []

if _missing("jax"):
    collect_ignore += [
        "test_beyond_paper.py",
        "test_cnn_models.py",
        "test_dryrun_method.py",
        "test_kernels.py",
        "test_lm_archs.py",
        "test_lm_components.py",
        "test_runtime.py",
    ]
