"""Multi-tenant co-scheduling: union graph structure, tenant-aware
scheduling invariants, per-tenant simulator metrics, elastic re-co-scheduling.

Deterministic seeded tests run everywhere; the hypothesis variants widen
the same invariants over random unions when the [test] extra is installed.
"""

import math

import pytest

from repro.core.cost import CostModel, HardwareProfile, make_pus
from repro.core.elastic import ElasticSession
from repro.core.graph import GraphError, MultiTenantGraph, OpKind, PUType
from repro.core.schedulers import available, get_scheduler
from repro.core.simulator import IMCESimulator, MultiTenantSimulator

from helpers import build_random_graph, given, settings, st

ROOMY = HardwareProfile(name="roomy", pu_weight_capacity=1e12)

ALL_ALGS = [a for a in available() if a != "optimal"]


def union_of(seeds, n_nodes=10, density=0.3):
    return MultiTenantGraph.union(
        [build_random_graph(n_nodes, density, s) for s in seeds],
        names=[f"t{s}" for s in seeds],
    )


class TestUnionStructure:
    def test_tagged_disjoint_union(self):
        g1 = build_random_graph(8, 0.3, seed=1)
        g2 = build_random_graph(12, 0.4, seed=2)
        mt = MultiTenantGraph.union([g1, g2], names=["a", "b"])
        mt.validate()
        assert mt.tenants == ["a", "b"]
        assert len(mt) == len(g1) + len(g2)
        assert set(mt.tenant_nodes("a")) | set(mt.tenant_nodes("b")) == set(mt.nodes)
        assert not set(mt.tenant_nodes("a")) & set(mt.tenant_nodes("b"))
        for t, g in (("a", g1), ("b", g2)):
            assert len(mt.tenant_sources(t)) == len(g.sources())
            assert len(mt.tenant_sinks(t)) == len(g.sinks())
            for nid in mt.tenant_nodes(t):
                assert mt.tenant_of(nid) == t
        # edges stay within a tenant (disjoint components)
        for s, d in mt.edges():
            assert mt.tenant_of(s) == mt.tenant_of(d)
        # id remap round-trips
        for old in g1.nodes:
            assert mt.tenant_of(mt.union_id("a", old)) == "a"

    def test_duplicate_model_names_deduplicated(self):
        g = build_random_graph(6, 0.3, seed=3)
        mt = MultiTenantGraph.union([g, g])
        assert mt.tenants == [g.name, f"{g.name}#1"]

    def test_duplicate_tenant_tag_rejected(self):
        g = build_random_graph(4, 0.3, seed=4)
        mt = MultiTenantGraph.union([g], names=["x"])
        with pytest.raises(GraphError):
            mt.add_tenant(g, "x")

    def test_empty_tenant_graph_rejected(self):
        from repro.core.graph import Graph
        with pytest.raises(GraphError):
            MultiTenantGraph.union([Graph("empty"),
                                    build_random_graph(4, 0.3, seed=9)])

    def test_json_round_trip_preserves_tenants(self):
        mt = union_of([7, 8])
        rt = MultiTenantGraph.from_json(mt.to_json())
        rt.validate()
        assert rt.tenants == mt.tenants
        for t in mt.tenants:
            assert rt.tenant_nodes(t) == mt.tenant_nodes(t)
            assert rt.tenant_sources(t) == mt.tenant_sources(t)
        for nid in mt.nodes:
            assert rt.tenant_of(nid) == mt.tenant_of(nid)
            # cost-model shape hints survive too
            assert rt.nodes[nid].meta == mt.nodes[nid].meta

    def test_tenant_longest_path_stays_in_tenant(self):
        mt = union_of([5, 6])
        cm = CostModel(ROOMY)
        for t in mt.tenants:
            lp = mt.tenant_longest_path(t, lambda n: cm.time(n))
            assert lp
            assert all(mt.tenant_of(n) == t for n in lp)


class TestMultiTenantScheduling:
    @pytest.mark.parametrize("alg", ALL_ALGS)
    def test_complete_and_compatible_on_union(self, alg):
        cm = CostModel(ROOMY)
        for seeds in ([11, 12], [13, 14, 15]):
            mt = union_of(seeds)
            fleet = make_pus(4, 2)
            a = get_scheduler(alg, cm).schedule(mt, fleet)
            a.validate(mt, cm, check_capacity=False)
            for node in mt.nodes.values():
                if node.is_free():
                    continue
                pu = a.pu_by_id(a.mapping[node.node_id])
                assert not math.isinf(cm.time(node, pu.pu_type, pu.speed))

    def test_tenant_load_sums_to_load(self):
        mt = union_of([21, 22, 23])
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(3, 2))
        total = a.load(mt, cm)
        by_tenant = a.tenant_load(mt, cm)
        assert set(by_tenant) == set(mt.tenants)
        for pid in total:
            s = sum(per_pu[pid] for per_pu in by_tenant.values())
            assert s == pytest.approx(total[pid], rel=1e-9, abs=1e-15)

    def test_lblp_mt_reduces_to_lblp_on_single_model(self):
        g = build_random_graph(14, 0.3, seed=31)
        cm = CostModel(ROOMY)
        fleet = make_pus(3, 2)
        m_lblp = get_scheduler("lblp", cm).schedule(g, fleet).mapping
        m_mt = get_scheduler("lblp-mt", cm).schedule(g, fleet).mapping
        assert m_lblp == m_mt

    def test_every_tenant_lp_gets_spread(self):
        """Each tenant's critical-path IMC nodes land on distinct PUs (the
        round-robin interleave gives every tenant LPT-style spreading)."""
        mt = union_of([41, 42], n_nodes=12)
        cm = CostModel(ROOMY)
        fleet = make_pus(4, 2)
        a = get_scheduler("lblp-mt", cm).schedule(mt, fleet)
        lps = a.meta["longest_paths"]
        assert set(lps) == set(mt.tenants)
        for t, lp in lps.items():
            typed = [n for n in lp if not mt.nodes[n].is_free()
                     and mt.nodes[n].pu_type == PUType.IMC]
            typed.sort(key=lambda n: -cm.time(mt.nodes[n]))
            k = min(len(typed), 2)  # 2 tenants on 4 IMC PUs -> >= 2 each
            assert len({a.mapping[n] for n in typed[:k]}) == k

    def test_mt_capacity_spill_recorded_and_assigned(self):
        """Same waiver contract as single-tenant LBLP: an infeasible node
        is still mapped, and the spill is recorded."""
        from repro.core.graph import Graph
        g1, g2 = Graph("m1"), Graph("m2")
        for g in (g1, g2):
            g.add("huge", OpKind.CONV, flops=1e6, weight_bytes=5e6,
                  out_bytes=1e3, out_elems=1e3,
                  meta=dict(cin_kk=64, cout=64, n_vectors=64))
        mt = MultiTenantGraph.union([g1, g2])
        prof = HardwareProfile(pu_weight_capacity=700e3)
        cm = CostModel(prof)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(2, 1, prof))
        assert sorted(a.meta["capacity_spills"]) == sorted(mt.tenant_nodes(mt.tenants[0])
                                                           + mt.tenant_nodes(mt.tenants[1]))
        assert set(a.mapping) == set(mt.nodes)  # waiver still assigns


class TestMultiTenantSimulator:
    def _run(self, seeds, n_imc=4, n_dpu=2, frames=32, rates=None):
        mt = union_of(seeds)
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(n_imc, n_dpu))
        sim = MultiTenantSimulator(mt, cm)
        return mt, sim.run(a, frames=frames, rates=rates)

    def test_rejects_single_tenant_graph(self):
        g = build_random_graph(6, 0.3, seed=51)
        with pytest.raises(TypeError):
            MultiTenantSimulator(g, CostModel(ROOMY))

    def test_per_tenant_metrics_sum_consistently(self):
        mt, r = self._run([52, 53], frames=32)
        assert set(r.tenants) == set(mt.tenants)
        # every tenant completed every injected frame
        for m in r.tenants.values():
            assert m.frames == 32
            assert m.rate > 0 and m.latency > 0
        assert r.frames == sum(m.frames for m in r.tenants.values())
        # tenant-attributed busy partitions the fleet's busy seconds
        for pid, total in r.busy.items():
            s = sum(m.busy.get(pid, 0.0) for m in r.tenants.values())
            assert s == pytest.approx(total, rel=1e-9, abs=1e-12)
        # utilization shares form a distribution
        shares = [m.utilization_share for m in r.tenants.values()]
        assert all(0.0 <= x <= 1.0 + 1e-9 for x in shares)
        assert sum(shares) == pytest.approx(1.0, abs=1e-9)
        # aggregate throughput ~ sum of tenant throughputs
        assert r.rate == pytest.approx(
            sum(m.rate for m in r.tenants.values()), rel=0.15)

    def test_aggregate_interval_respects_union_bound(self):
        """One 'round' completes one frame of every tenant, so the analytic
        max-load bound applies to num_tenants * interval (same estimator
        tolerance as the single-tenant invariant)."""
        mt, r = self._run([54, 55], frames=64)
        assert len(mt.tenants) * r.interval >= r.bound_interval * 0.9

    def test_open_loop_rates_are_independent(self):
        mt = union_of([56, 57])
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(4, 2))
        sim = MultiTenantSimulator(mt, cm)
        sat = sim.run(a, frames=32)
        # throttle each tenant to half its saturated rate -> delivered
        # rate tracks the requested rate, not the saturated one
        rates = {t: sat.tenants[t].rate * 0.5 for t in mt.tenants}
        r = sim.run(a, frames=32, rates=rates)
        for t in mt.tenants:
            assert r.tenants[t].injected_rate == pytest.approx(rates[t])
            assert r.tenants[t].rate == pytest.approx(rates[t], rel=0.2)
            assert r.tenants[t].frames == 32

    def test_rates_must_cover_all_tenants(self):
        mt = union_of([58, 59])
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(2, 1))
        sim = MultiTenantSimulator(mt, cm)
        with pytest.raises(ValueError):
            sim.run(a, frames=8, rates={mt.tenants[0]: 100.0})

    def test_deterministic(self):
        _, r1 = self._run([61, 62], frames=24)
        _, r2 = self._run([61, 62], frames=24)
        assert r1.interval == r2.interval
        assert {t: m.rate for t, m in r1.tenants.items()} == \
               {t: m.rate for t, m in r2.tenants.items()}


class TestCoVsStaticPartition:
    def test_coscheduling_2p_never_worse_than_half_fleet_split(self):
        """Identical pair on 2P PUs: co-scheduled aggregate rate matches or
        beats the better static half-fleet split.  The *optimal*
        co-schedule can always emulate the partition; greedy lblp-mt can
        fall short on adversarial random DAGs, so this pins the behaviour
        on fixed seeds (deterministic) rather than quantifying over all
        graphs — the CNN-model benchmark covers the realistic shapes."""
        cm = CostModel(ROOMY)
        for seed in (71, 37, 73):
            g = build_random_graph(12, 0.3, seed)
            # static: each copy alone on half the fleet (2 IMC + 1 DPU)
            half = make_pus(2, 1)
            a_half = get_scheduler("lblp", cm).schedule(g, half)
            r_half = IMCESimulator(g, cm).run(a_half, frames=64)
            static_total = 2 * r_half.rate  # both halves identical
            # co-scheduled union on the full fleet (4 IMC + 2 DPU)
            mt = MultiTenantGraph.union([g, g])
            a_co = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(4, 2))
            r_co = MultiTenantSimulator(mt, cm).run(a_co, frames=64)
            co_total = sum(m.rate for m in r_co.tenants.values())
            assert co_total >= static_total * 0.95, seed


class TestElasticMultiTenant:
    def test_failure_recoschedules_all_tenants(self):
        mt = union_of([81, 82])
        cm = CostModel(ROOMY)
        sess = ElasticSession(mt, make_pus(4, 2), cost_model=cm)
        assert sess.algorithm == "lblp-mt"
        e0 = sess.history[0]
        assert set(e0.tenant_rates) == set(mt.tenants)
        ev = sess.fail(2)
        assert ev.n_pus == 5
        # the whole union is re-placed on survivors in one pass
        assert set(ev.mapping) == set(e0.mapping)
        assert 2 not in set(ev.mapping.values())
        assert set(ev.tenant_rates) == set(mt.tenants)
        assert all(r > 0 for r in ev.tenant_rates.values())


# -- property-based widening (skipped cleanly without hypothesis) -----------

two_seeds_st = st.tuples(st.integers(0, 5000), st.integers(5001, 10_000))


class TestProperties:
    @given(seeds=two_seeds_st, n_imc=st.integers(1, 4),
           alg=st.sampled_from(ALL_ALGS))
    @settings(max_examples=60, deadline=None)
    def test_schedulers_complete_on_random_unions(self, seeds, n_imc, alg):
        cm = CostModel(ROOMY)
        mt = union_of(list(seeds), n_nodes=8)
        a = get_scheduler(alg, cm).schedule(mt, make_pus(n_imc, 2))
        a.validate(mt, cm, check_capacity=False)

    @given(seeds=two_seeds_st)
    @settings(max_examples=20, deadline=None)
    def test_tenant_busy_partitions_fleet_busy(self, seeds):
        cm = CostModel(ROOMY)
        mt = union_of(list(seeds), n_nodes=8)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(3, 2))
        r = MultiTenantSimulator(mt, cm).run(a, frames=16)
        for pid, total in r.busy.items():
            s = sum(m.busy.get(pid, 0.0) for m in r.tenants.values())
            assert s == pytest.approx(total, rel=1e-9, abs=1e-12)

    @given(seeds=two_seeds_st)
    @settings(max_examples=20, deadline=None)
    def test_mt_interval_respects_bound(self, seeds):
        cm = CostModel(ROOMY)
        mt = union_of(list(seeds), n_nodes=8)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(3, 2))
        r = MultiTenantSimulator(mt, cm).run(a, frames=48)
        assert len(mt.tenants) * r.interval >= r.bound_interval * 0.9
