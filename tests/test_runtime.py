"""Runtime-tier tests: checkpoint atomicity/resume, data determinism,
fault-tolerant training loop, straggler policy, gradient compression,
elastic rescheduling, serving loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core import make_pus
from repro.core.elastic import ElasticSession
from repro.core.pipeline_partition import partition, transformer_block_graph
from repro.data.pipeline import DataConfig, DataIterator, make_batch
from repro.models.cnn.graphs import resnet18_graph
from repro.models.lm import transformer
from repro.optim import adamw, compression
from repro.runtime.serve_loop import Request, Server
from repro.runtime.straggler import DeadlineDataIterator, StragglerPolicy
from repro.runtime.train_loop import TrainLoopConfig, train

SMOKE = get_config("stablelm-1.6b").smoke()
TRAIN_SHAPE = ShapeSpec("rt-train", 32, 8, "train")


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": [jnp.ones((4,), jnp.bfloat16),
                      {"c": jnp.asarray(3, jnp.int32)}]}
        ckpt.save(str(tmp_path), 5, tree, extras={"note": "x"})
        out, extras = ckpt.restore(str(tmp_path), 5, tree)
        assert extras["note"] == "x"
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype

    def test_latest_ignores_uncommitted(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        ckpt.save(str(tmp_path), 1, tree)
        ckpt.save(str(tmp_path), 2, tree)
        # fake a torn write: directory without COMMITTED marker
        os.makedirs(tmp_path / "step_000000003")
        assert ckpt.latest_step(str(tmp_path)) == 2

    def test_prune_keeps_newest(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            ckpt.save(str(tmp_path), s, tree)
        ckpt.prune(str(tmp_path), keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 4
        assert ckpt.restore_latest(str(tmp_path), tree) is not None
        assert not os.path.exists(tmp_path / "step_000000001")

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros((3,))})


class TestData:
    def test_deterministic_per_step(self):
        b1 = make_batch(SMOKE, TRAIN_SHAPE, 7)
        b2 = make_batch(SMOKE, TRAIN_SHAPE, 7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = make_batch(SMOKE, TRAIN_SHAPE, 8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_resume_replays_stream(self):
        it1 = DataIterator(SMOKE, TRAIN_SHAPE, start_step=0)
        seen = [next(it1)["tokens"] for _ in range(5)]
        it2 = DataIterator(SMOKE, TRAIN_SHAPE, start_step=3)
        np.testing.assert_array_equal(next(it2)["tokens"], seen[3])

    def test_host_sharding_disjoint(self):
        d0 = DataConfig(num_hosts=2, host_id=0)
        d1 = DataConfig(num_hosts=2, host_id=1)
        b0 = make_batch(SMOKE, TRAIN_SHAPE, 0, d0)
        b1 = make_batch(SMOKE, TRAIN_SHAPE, 0, d1)
        assert b0["tokens"].shape[0] == TRAIN_SHAPE.global_batch // 2
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_tokens_in_vocab(self):
        b = make_batch(SMOKE, TRAIN_SHAPE, 0)
        assert int(b["tokens"].max()) < SMOKE.vocab
        assert int(b["tokens"].min()) >= 0


class TestTrainLoop:
    def _loop_cfg(self, tmp_path, total=6):
        return TrainLoopConfig(
            total_steps=total, ckpt_every=2, ckpt_dir=str(tmp_path),
            log_every=0,
            opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100))

    def test_runs_and_checkpoints(self, tmp_path):
        rep = train(SMOKE, TRAIN_SHAPE, self._loop_cfg(tmp_path))
        assert rep.final_step == 6
        assert ckpt.latest_step(str(tmp_path)) == 6
        assert all(np.isfinite(rep.losses))

    def test_resume_after_interruption(self, tmp_path):
        train(SMOKE, TRAIN_SHAPE, self._loop_cfg(tmp_path, total=4))
        rep = train(SMOKE, TRAIN_SHAPE, self._loop_cfg(tmp_path, total=8))
        assert rep.resumed_from == 4
        assert rep.steps_run == 4
        assert rep.final_step == 8

    def test_transient_fault_retried(self, tmp_path):
        fails = {"left": 2}

        def hook(step):
            if step == 2 and fails["left"] > 0:
                fails["left"] -= 1
                raise RuntimeError("injected device failure")

        rep = train(SMOKE, TRAIN_SHAPE, self._loop_cfg(tmp_path),
                    fault_hook=hook)
        assert rep.retries == 2
        assert rep.final_step == 6

    def test_persistent_fault_leaves_consistent_ckpt(self, tmp_path):
        def hook(step):
            if step == 3:
                raise RuntimeError("dead node")

        with pytest.raises(RuntimeError):
            train(SMOKE, TRAIN_SHAPE, self._loop_cfg(tmp_path),
                  fault_hook=hook)
        # a committed checkpoint exists and a fresh run resumes cleanly
        assert ckpt.latest_step(str(tmp_path)) is not None
        rep = train(SMOKE, TRAIN_SHAPE, self._loop_cfg(tmp_path))
        assert rep.resumed_from is not None


class TestStraggler:
    def test_slow_batches_substituted(self):
        slow_steps = {3, 4}
        src = DataIterator(SMOKE, TRAIN_SHAPE, start_step=0,
                           delay_fn=lambda s: 0.3 if s in slow_steps else 0.0)
        pol = StragglerPolicy(slack=2.0, min_deadline_s=0.1)
        it = DeadlineDataIterator(SMOKE, TRAIN_SHAPE, src, pol)
        for _ in range(6):
            b = next(it)
            assert b["tokens"].shape[0] == TRAIN_SHAPE.global_batch
        assert pol.drops == len(slow_steps)

    def test_escalation_fires(self):
        src = DataIterator(SMOKE, TRAIN_SHAPE, start_step=0,
                           delay_fn=lambda s: 0.2 if s > 0 else 0.0)
        pol = StragglerPolicy(slack=1.5, min_deadline_s=0.05,
                              escalate_after=3)
        fired = []
        it = DeadlineDataIterator(SMOKE, TRAIN_SHAPE, src, pol,
                                  on_escalate=lambda: fired.append(1))
        for _ in range(6):
            next(it)
        assert fired


class TestCompression:
    def test_roundtrip_error_bounded(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
        st = compression.init(g)
        q, s, st = compression.compress(g, st)
        back = compression.decompress(q, s)
        err = jnp.max(jnp.abs(back["w"] - g["w"]))
        assert float(err) <= float(s["w"]) * 0.5 + 1e-7

    def test_error_feedback_unbiased_over_steps(self):
        """With a CONSTANT gradient, error feedback makes the mean of the
        decompressed stream converge to the true gradient."""
        g = {"w": jax.random.normal(jax.random.PRNGKey(1), (32,)) * 0.01}
        st = compression.init(g)
        acc = jnp.zeros((32,))
        n = 50
        for _ in range(n):
            q, s, st = compression.compress(g, st)
            acc = acc + compression.decompress(q, s)["w"]
        np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                                   rtol=0.02, atol=1e-5)

    def test_traffic_reduction(self):
        g = {"w": jnp.zeros((1000,), jnp.float32)}
        st = compression.init(g)
        q, s, _ = compression.compress(g, st)
        assert compression.compressed_bytes(q) * 4 == compression.raw_bytes(g)


class TestElastic:
    def test_failure_degrades_gracefully(self):
        g = resnet18_graph()
        sess = ElasticSession(g, make_pus(8, 4))
        r0 = sess.history[0].rate
        ev = sess.fail(3)
        assert ev.n_pus == 11
        assert 0.5 * r0 <= ev.rate <= r0 * 1.001
        # mapping no longer uses the dead PU
        assert 3 not in set(ev.mapping.values())

    def test_rejoin_recovers(self):
        from repro.core import PUSpec, PUType
        g = resnet18_graph()
        sess = ElasticSession(g, make_pus(8, 4))
        r0 = sess.history[0].rate
        sess.fail(5)
        ev = sess.join(PUSpec(pu_id=5, pu_type=PUType.IMC))
        assert ev.rate == pytest.approx(r0, rel=1e-6)

    def test_sequence_of_failures(self):
        """Rate degrades gracefully over successive failures (LBLP is a
        greedy heuristic, so single steps may wobble slightly — the
        invariant is bounded degradation, ending below the start)."""
        g = resnet18_graph()
        sess = ElasticSession(g, make_pus(8, 4))
        r0 = sess.history[0].rate
        rates = [r0]
        for pid in (1, 2, 9):
            rates.append(sess.fail(pid).rate)
        assert all(r <= r0 * 1.05 for r in rates)
        assert rates[-1] <= r0
        assert rates[-1] >= r0 * 0.4          # graceful, not collapse


class TestServeLoop:
    def _setup(self, fault_hook=None):
        cfg = SMOKE
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, Server(cfg, params, max_batch=2, s_max=64,
                           fault_hook=fault_hook)

    def test_serves_batch_of_requests(self):
        cfg, server = self._setup()
        reqs = [Request(rid=i,
                        prompt=jax.random.randint(
                            jax.random.PRNGKey(i), (8,), 0, cfg.vocab,
                            dtype=jnp.int32),
                        max_new=4)
                for i in range(5)]
        stats = server.serve(reqs)
        assert stats.served == 5
        assert stats.prefills >= 3          # ceil(5/2) batches
        for r in reqs:
            assert len(r.out_tokens) == 4
            assert all(0 <= t < cfg.vocab for t in r.out_tokens)

    def test_decode_failure_recovers_by_reprefill(self):
        calls = {"n": 0}

        def hook(step):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("injected decode failure")

        cfg, server = self._setup(fault_hook=hook)
        reqs = [Request(rid=0, prompt=jnp.arange(6, dtype=jnp.int32),
                        max_new=4)]
        stats = server.serve(reqs)
        assert stats.retries == 1
        assert len(reqs[0].out_tokens) == 4


class TestPipelinePartition:
    def test_dense_partition_balanced(self):
        plan = partition(get_config("stablelm-1.6b"), n_stages=4)
        assert len(plan.loads) == 4
        assert plan.imbalance < 1.35

    def test_moe_partition_handles_heterogeneity(self):
        plan = partition(get_config("qwen3-moe-235b-a22b"), n_stages=8)
        assert plan.imbalance < 1.5
        assert len(plan.boundaries) == 8

    def test_block_graph_counts(self):
        cfg = get_config("recurrentgemma-9b")
        g = transformer_block_graph(cfg, 2048)
        # embed + 38 blocks + head
        assert len(g) == cfg.n_layers + 2
