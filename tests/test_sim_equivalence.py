"""Simulator-unification equivalence pins.

The tenant-keyed event loop (``IMCESimulator._run_streams``) replaced two
near-duplicate loops (the historical single-tenant ``_simulate`` and
``MultiTenantSimulator._simulate_mt``).  These tests pin the unified
loop's output against golden values captured from the pre-unification
simulator on the paper-validation graphs: every ``SimResult`` field
(rate, latency, utilization, makespan) and the raw event-loop outputs
(per-frame completion times, sojourns, busy intervals) must be
*bit-identical* — the single-tenant run is the 1-stream special case and
its ready-queue order is provably unchanged.

Regenerating the goldens is only legitimate after an intentional
semantic change to the execution model; see tests/data/.
"""

import json
import pathlib

from repro.core import (CostModel, IMCESimulator, MultiTenantSimulator,
                        get_scheduler, make_pus)
from repro.core.graph import MultiTenantGraph
from repro.models.cnn.graphs import resnet8_graph, resnet18_graph

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "data" / "golden_simulator.json")
    .read_text())

GRAPHS = {"resnet8": resnet8_graph, "resnet18": resnet18_graph}
FLEETS = [(2, 1), (4, 2), (8, 4)]
ALGS = ("lblp", "rr", "wb")


def result_fields(r):
    return dict(
        latency=r.latency, latency_isolated=r.latency_isolated,
        interval=r.interval, rate=r.rate, makespan=r.makespan,
        frames=r.frames, mean_utilization=r.mean_utilization,
        bound_interval=r.bound_interval,
        busy={str(k): v for k, v in sorted(r.busy.items())},
        utilization={str(k): v for k, v in sorted(r.utilization.items())},
    )


class TestSingleTenantEquivalence:
    def test_simresults_bit_identical(self):
        cm = CostModel()
        checked = 0
        for gname, gfn in GRAPHS.items():
            for n_imc, n_dpu in FLEETS:
                for alg in ALGS:
                    g = gfn()
                    a = get_scheduler(alg, cm).schedule(
                        g, make_pus(n_imc, n_dpu))
                    r = IMCESimulator(g, cm).run(a, frames=64)
                    got = result_fields(r)
                    exp = GOLDEN[f"{gname}/{alg}/{n_imc}+{n_dpu}"]
                    for fld, v in exp.items():
                        assert got[fld] == v, (gname, alg, n_imc, n_dpu, fld)
                    checked += 1
        assert checked == len(GRAPHS) * len(FLEETS) * len(ALGS)

    def test_raw_event_loop_outputs_bit_identical(self):
        """Completion times, sojourns and busy intervals of the raw loop —
        the strongest form of 'the ready-queue order did not change'."""
        cm = CostModel()
        for gname, gfn in GRAPHS.items():
            g = gfn()
            a = get_scheduler("lblp", cm).schedule(g, make_pus(4, 2))
            makespan, completions, busy, sojourns = IMCESimulator(
                g, cm)._simulate(a, frames=24, in_flight=6)
            exp = GOLDEN[f"{gname}/lblp/4+2/raw"]
            assert makespan == exp["makespan"], gname
            assert completions == exp["completions"], gname
            assert sojourns == exp["sojourns"], gname
            got_busy = {str(k): [list(iv) for iv in v]
                        for k, v in sorted(busy.items())}
            assert got_busy == exp["busy_iv"], gname


class TestMultiTenantEquivalence:
    def test_mt_simresult_bit_identical(self):
        cm = CostModel()
        mt = MultiTenantGraph.union([resnet8_graph(), resnet18_graph()])
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(8, 4))
        r = MultiTenantSimulator(mt, cm).run(a, frames=32)
        exp = GOLDEN["mt/lblp-mt/8+4"]
        got = result_fields(r)
        for fld, v in exp.items():
            if fld == "tenants":
                continue
            assert got[fld] == v, fld
        for t, tm in exp["tenants"].items():
            m = r.tenants[t]
            got_t = dict(rate=m.rate, interval=m.interval, latency=m.latency,
                         frames=m.frames,
                         utilization_share=m.utilization_share)
            assert got_t == tm, t


class TestOneEventLoop:
    def test_no_duplicate_loop_remains(self):
        """The tech-debt contract: MultiTenantSimulator must not carry its
        own event loop — one shared implementation only."""
        assert not hasattr(MultiTenantSimulator, "_simulate_mt")
        assert (MultiTenantSimulator._run_streams
                is IMCESimulator._run_streams)
        assert MultiTenantSimulator._simulate is IMCESimulator._simulate
