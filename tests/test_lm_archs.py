"""Per-architecture smoke tests (reduced configs, CPU): one train step +
prefill/decode round trip, shape and finiteness assertions; full-config
parameter counts sanity (assignment deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_archs, get_config
from repro.configs.base import ShapeSpec
from repro.models.lm import model, transformer
from repro.optim import adamw

TRAIN_SHAPE = ShapeSpec("smoke-train", 32, 8, "train")
SERVE_SHAPE = ShapeSpec("smoke-serve", 32, 4, "prefill")

#: full-config parameter-count windows (billions) — sanity vs the model
#: names; MoE counts are total (active checked separately).
PARAM_WINDOWS = {
    "granite-moe-3b-a800m": (2.5, 4.0),
    "qwen3-moe-235b-a22b": (200.0, 260.0),
    "falcon-mamba-7b": (6.0, 8.0),
    "stablelm-1.6b": (1.2, 1.9),
    "gemma3-1b": (0.8, 1.3),
    "gemma2-27b": (24.0, 30.0),
    "starcoder2-3b": (2.5, 3.5),
    "whisper-small": (0.15, 0.35),
    "paligemma-3b": (2.0, 3.2),    # LM backbone (SigLIP stubbed)
    "recurrentgemma-9b": (8.0, 11.0),
}


@pytest.fixture(scope="module", params=all_archs())
def arch(request):
    return request.param


class TestSmoke:
    def test_train_step(self, arch):
        cfg = get_config(arch).smoke()
        key = jax.random.PRNGKey(0)
        params = transformer.init_params(cfg, key)
        batch = model.synth_batch(cfg, TRAIN_SHAPE, key)
        step = jax.jit(model.make_train_step(cfg))
        opt = adamw.init(params)
        p2, o2, metrics = step(params, opt, batch)
        assert jnp.isfinite(metrics["loss"])
        assert metrics["loss"] > 0
        assert int(o2.step) == 1
        # params actually changed
        diff = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            params, p2)
        assert max(jax.tree_util.tree_leaves(diff)) > 0

    def test_loss_decreases_over_steps(self, arch):
        cfg = get_config(arch).smoke()
        key = jax.random.PRNGKey(1)
        params = transformer.init_params(cfg, key)
        batch = model.synth_batch(cfg, TRAIN_SHAPE, key)  # fixed batch
        tcfg = model.TrainStepConfig(opt=adamw.AdamWConfig(
            lr=3e-3, warmup_steps=1, total_steps=1000, weight_decay=0.0))
        step = jax.jit(model.make_train_step(cfg, tcfg))
        opt = adamw.init(params)
        losses = []
        for _ in range(6):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]   # overfits a fixed batch

    def test_prefill_decode_roundtrip(self, arch):
        cfg = get_config(arch).smoke()
        key = jax.random.PRNGKey(0)
        params = transformer.init_params(cfg, key)
        batch = model.synth_batch(cfg, SERVE_SHAPE, key)
        prefill = jax.jit(model.make_prefill_step(cfg, s_max=64))
        decode = jax.jit(model.make_decode_step(cfg))
        logits, cache = prefill(params, batch)
        assert logits.shape[0] == SERVE_SHAPE.global_batch
        assert logits.shape[-1] == cfg.vocab
        assert jnp.isfinite(logits).all()
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        for _ in range(3):
            logits, cache = decode(params, tok, cache)
            assert jnp.isfinite(logits).all()
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)

    def test_decode_matches_teacher_forcing(self, arch):
        """Incremental decode must agree with full-sequence forward on the
        same token stream (cache correctness)."""
        if arch == "whisper-small":
            pytest.skip("enc-dec full-forward comparison covered separately")
        cfg = get_config(arch).smoke()
        if cfg.n_experts:
            # capacity-MoE drops are sequence-length dependent, so decode
            # vs teacher-forcing only agree when nothing drops
            import dataclasses
            cfg = dataclasses.replace(
                cfg, capacity_factor=2.0 * cfg.n_experts / cfg.top_k)
        key = jax.random.PRNGKey(2)
        params = transformer.init_params(cfg, key)
        B, S = 2, 12
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
        prefix = None
        if cfg.num_prefix_tokens:
            prefix = jax.random.normal(
                key, (B, cfg.num_prefix_tokens, cfg.prefix_dim),
                jnp.bfloat16)
        # full forward logits at the last position
        hidden = transformer.forward_train(cfg, params, tokens, prefix=prefix)
        full_logits = transformer.logits_head(cfg, params, hidden[:, -1:])
        # prefill on the first S-1 tokens, decode token S-1
        batch = {"tokens": tokens[:, :-1]}
        if prefix is not None:
            batch["prefix"] = prefix
        _, cache = model.make_prefill_step(cfg, s_max=32)(params, batch)
        dec_logits, _ = model.make_decode_step(cfg)(
            params, tokens[:, -1:], cache)
        # bf16 stack + different reduction orders: modest tolerance
        a = jax.nn.log_softmax(full_logits[:, 0])
        b = jax.nn.log_softmax(dec_logits[:, 0])
        err = jnp.max(jnp.abs(a - b))
        assert err < 0.12, float(err)
        agree = jnp.mean((jnp.argmax(a, -1) == jnp.argmax(b, -1))
                         .astype(jnp.float32))
        assert agree >= 0.5


class TestFullConfigs:
    def test_param_counts(self, arch):
        lo, hi = PARAM_WINDOWS[arch]
        n = transformer.param_count(get_config(arch)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"

    def test_layer_counts(self, arch):
        cfg = get_config(arch)
        expected = {
            "granite-moe-3b-a800m": 32, "qwen3-moe-235b-a22b": 94,
            "falcon-mamba-7b": 64, "stablelm-1.6b": 24, "gemma3-1b": 26,
            "gemma2-27b": 46, "starcoder2-3b": 30, "whisper-small": 12,
            "paligemma-3b": 18, "recurrentgemma-9b": 38,
        }[arch]
        assert cfg.n_layers == expected

    def test_moe_active_params(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        total = transformer.param_count(cfg)
        inactive = (cfg.n_experts - cfg.top_k) * cfg.n_layers * 3 \
            * cfg.d_model * cfg.d_ff
        active = (total - inactive) / 1e9
        assert 15.0 <= active <= 26.0     # "a22b"
