"""Property tests for the compiled simulation core.

The compiled loop (``IMCESimulator._run_streams`` over a precompiled
``SimContext``) must reproduce the frozen reference loop
(``repro.core._sim_reference``) *bit-identically* in the default exact
mode — on random DAGs x random fleets x random schedulers x random
replica configurations, single- and multi-tenant.  The quantized
"periodic" mode (steady-state early exit) must agree with its own full
simulation exactly on the drain-free prefix and with exact mode within
the cost-quantization tolerance.

Deterministic variants run everywhere (jax-free, stdlib-only);
hypothesis widens the sweep when the [test] extra is installed
(``tests/helpers.py`` shims keep collection clean without it).
"""

import pytest

from repro.core import CostModel, make_pus, make_simulator
from repro.core.cost import HardwareProfile
from repro.core.graph import MultiTenantGraph
from repro.core.schedulers import get_scheduler
from repro.core.simulator import IMCESimulator

from helpers import build_random_graph, given, settings, st

ROOMY = HardwareProfile(name="roomy", pu_weight_capacity=1e12)

ALGS = ("lblp", "rr", "wb")


def replicate_some(g, seed: int, max_k: int = 3):
    """Deterministically replicate up to two non-free nodes of ``g``."""
    cands = [n.node_id for n in g.nodes.values() if not n.is_free()]
    if not cands:
        return g
    counts = {}
    for i, nid in enumerate(sorted(cands)):
        if (nid + seed + i) % 3 == 0 and len(counts) < 2:
            counts[nid] = 2 + (nid + seed) % (max_k - 1)
    return g.with_replicas(counts)


def run_both(g, alg: str, n_imc: int, n_dpu: int, frames: int,
             in_flight: int, cm=None):
    cm = cm or CostModel(ROOMY)
    a = get_scheduler(alg, cm).schedule(g, make_pus(n_imc, n_dpu))
    new = make_simulator(g, cm, engine="exact")
    ref = make_simulator(g, cm, engine="reference")
    if isinstance(g, MultiTenantGraph):
        got = new._run_streams(a, frames, in_flight=in_flight)
        exp = ref._run_streams(a, frames, in_flight=in_flight)
    else:
        got = new._simulate(a, frames=frames, in_flight=in_flight)
        exp = ref._simulate(a, frames=frames, in_flight=in_flight)
    return got, exp


class TestExactEquivalence:
    """Compiled exact mode == reference loop, bit for bit."""

    def check(self, g, alg, n_imc, n_dpu, frames=24, in_flight=5):
        got, exp = run_both(g, alg, n_imc, n_dpu, frames, in_flight)
        assert got == exp, (g.name, alg, n_imc, n_dpu)

    def test_random_graphs(self):
        for seed in (0, 1, 7, 23, 99):
            g = build_random_graph(14, 0.3, seed)
            for alg in ALGS:
                self.check(g, alg, 4, 2)

    def test_replicated_random_graphs(self):
        for seed in (2, 5, 11, 42):
            g = replicate_some(build_random_graph(12, 0.35, seed), seed)
            self.check(g, "lblp", 5, 2)

    def test_dynamic_phase_fallback(self):
        """Replica-count lcm beyond MAX_PHASE_PERIOD falls back to
        per-injection activity computation — still bit-identical."""
        from repro.core.simcontext import MAX_PHASE_PERIOD
        g = build_random_graph(10, 0.3, 31, imc_fraction=1.0)
        cands = sorted(n.node_id for n in g.nodes.values() if not n.is_free())
        g2 = g.with_replicas({cands[0]: 5, cands[1]: 13, cands[2]: 7})
        cm = CostModel(ROOMY)
        ctx = IMCESimulator(g2, cm)._ctx
        assert not ctx.phases_compiled  # lcm(5,13,7)=455 > cap
        assert 5 * 13 * 7 > MAX_PHASE_PERIOD
        got, exp = run_both(g2, "lblp", 6, 2, frames=30, in_flight=6)
        assert got == exp

    def test_multi_tenant_union(self):
        mt = MultiTenantGraph.union(
            [build_random_graph(8, 0.3, 3), build_random_graph(10, 0.4, 4)])
        got, exp = run_both(mt, "lblp-mt", 4, 2, frames=16, in_flight=4)
        assert got == exp

    def test_multi_tenant_replicated_union(self):
        mt = MultiTenantGraph.union(
            [build_random_graph(8, 0.3, 6), build_random_graph(9, 0.35, 7)])
        mt = replicate_some(mt, 1)
        got, exp = run_both(mt, "lblp-mt", 5, 3, frames=16, in_flight=4)
        assert got == exp

    def test_open_loop_rates(self):
        cm = CostModel(ROOMY)
        mt = MultiTenantGraph.union(
            [build_random_graph(6, 0.3, 8), build_random_graph(7, 0.3, 9)])
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(4, 2))
        rates = {t: 500.0 + 100 * i for i, t in enumerate(mt.tenants)}
        new = make_simulator(mt, cm, engine="exact")
        ref = make_simulator(mt, cm, engine="reference")
        got = new._run_streams(a, 12, in_flight=0, rates=rates)
        exp = ref._run_streams(a, 12, in_flight=0, rates=rates)
        assert got == exp

    @given(seed=st.integers(0, 5000), n_imc=st.integers(2, 6),
           alg=st.sampled_from(ALGS), in_flight=st.integers(1, 8))
    @settings(max_examples=30, deadline=None)
    def test_property_random(self, seed, n_imc, alg, in_flight):
        g = replicate_some(build_random_graph(12, 0.3, seed), seed)
        got, exp = run_both(g, alg, n_imc, 2, frames=20, in_flight=in_flight)
        assert got == exp


class TestPeriodicMode:
    """Quantized early-exit runs agree with their own full simulation
    exactly (modulo the budget-cut drain tail) and with exact mode
    within the cost-quantization tolerance."""

    def _periodic_pair(self, g, alg, n_imc, n_dpu, frames, in_flight):
        """(early-exit run, full quantized run) over the same schedule."""
        cm = CostModel(ROOMY)
        a = get_scheduler(alg, cm).schedule(g, make_pus(n_imc, n_dpu))
        fast = make_simulator(g, cm, engine="periodic")
        got = fast._simulate(a, frames=frames, in_flight=in_flight)
        fired = fast.last_early_exit
        slow = make_simulator(g, cm, engine="periodic")
        # a fresh context would be shared via the graph cache; disable
        # detection by monkey-free means: raise the arming threshold
        import repro.core.simulator as simmod
        old = simmod._DETECT_MIN_FRAMES
        simmod._DETECT_MIN_FRAMES = frames + 1
        try:
            exp = slow._simulate(a, frames=frames, in_flight=in_flight)
        finally:
            simmod._DETECT_MIN_FRAMES = old
        assert slow.last_early_exit is None
        return got, exp, fired

    def check_periodic(self, g, alg="lblp", n_imc=4, n_dpu=2,
                       frames=96, in_flight=5):
        got, exp, fired = self._periodic_pair(
            g, alg, n_imc, n_dpu, frames, in_flight)
        mk_g, comp_g, busy_g, soj_g = got
        mk_e, comp_e, busy_e, soj_e = exp
        assert len(comp_g) == len(comp_e) == frames
        # the budget cut relaxes contention only for the trailing
        # ~in_flight frames; everything before is exactly periodic
        safe = frames - 2 * in_flight - 4
        assert comp_g[:safe] == comp_e[:safe], (g.name, alg, fired)
        assert soj_g[:safe] == soj_e[:safe], (g.name, alg, fired)
        # aggregate rate agrees tightly even across the tail
        rate_g = (len(comp_g) - 1) / (comp_g[-1] - comp_g[0])
        rate_e = (len(comp_e) - 1) / (comp_e[-1] - comp_e[0])
        assert rate_g == pytest.approx(rate_e, rel=0.05)
        assert sum(e - b for b, e in
                   (iv for ivs in busy_g.values() for iv in ivs)) > 0

    def test_random_graphs_fire_and_agree(self):
        fired_any = False
        for seed in (0, 3, 9, 21):
            g = build_random_graph(12, 0.3, seed)
            got, exp, fired = self._periodic_pair(g, "lblp", 4, 2, 96, 5)
            fired_any = fired_any or fired is not None
            self.check_periodic(g)
        assert fired_any, "steady-state exit never fired on any seed"

    def test_replicated_graphs(self):
        for seed in (4, 13):
            g = replicate_some(build_random_graph(10, 0.35, seed), seed)
            self.check_periodic(g)

    def test_periodic_vs_exact_rate(self):
        """Quantization + steady-state sampling stay within ~5% of the
        exact-mode figures on random workloads (they usually agree to
        <1e-2; the bound here is deliberately loose)."""
        cm = CostModel(ROOMY)
        for seed in (1, 6, 17):
            g = build_random_graph(12, 0.3, seed)
            a = get_scheduler("lblp", cm).schedule(g, make_pus(4, 2))
            r_ex = make_simulator(g, cm, engine="exact").run(a, frames=96)
            r_pe = make_simulator(g, cm, engine="periodic").run(a, frames=96)
            assert r_pe.rate == pytest.approx(r_ex.rate, rel=0.05)
            assert r_pe.latency == pytest.approx(r_ex.latency, rel=0.05)
            assert r_pe.mean_utilization == pytest.approx(
                r_ex.mean_utilization, rel=0.05)

    @given(seed=st.integers(0, 5000), n_imc=st.integers(3, 6),
           in_flight=st.integers(2, 8))
    @settings(max_examples=15, deadline=None)
    def test_property_periodic(self, seed, n_imc, in_flight):
        g = replicate_some(build_random_graph(11, 0.3, seed), seed)
        self.check_periodic(g, n_imc=n_imc, in_flight=in_flight)


class TestPeriodicMultiTenant:
    def test_open_loop_rates_quantized_grid(self):
        """Open-loop injection times must live on the tick grid too:
        a periodic-mode rates run has to reproduce the requested
        per-tenant rates, not a ticks/seconds unit mix."""
        cm = CostModel(ROOMY)
        mt = MultiTenantGraph.union(
            [build_random_graph(6, 0.3, 22), build_random_graph(7, 0.3, 23)])
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(4, 2))
        rates = {t: 40.0 + 10 * i for i, t in enumerate(mt.tenants)}
        r_ex = make_simulator(mt, cm, engine="exact").run(
            a, frames=32, rates=rates)
        r_pe = make_simulator(mt, cm, engine="periodic").run(
            a, frames=32, rates=rates)
        assert r_pe.makespan == pytest.approx(r_ex.makespan, rel=1e-6)
        for t in mt.tenants:
            assert r_pe.tenants[t].rate == pytest.approx(
                r_ex.tenants[t].rate, rel=1e-3)
            assert r_pe.tenants[t].latency == pytest.approx(
                r_ex.tenants[t].latency, rel=1e-3)

    def test_mt_periodic_close_to_exact(self):
        """Multi-stream runs never early-exit (fair-queueing interleave
        is not frame-shift invariant) but still run on the quantized
        grid; aggregate and per-tenant figures stay close to exact."""
        cm = CostModel(ROOMY)
        mt = MultiTenantGraph.union(
            [build_random_graph(8, 0.3, 12), build_random_graph(9, 0.3, 13)])
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(5, 3))
        r_ex = make_simulator(mt, cm, engine="exact").run(a, frames=48)
        pe = make_simulator(mt, cm, engine="periodic")
        r_pe = pe.run(a, frames=48)
        assert pe.last_early_exit is None
        assert r_pe.rate == pytest.approx(r_ex.rate, rel=0.05)
        for t in mt.tenants:
            assert r_pe.tenants[t].rate == pytest.approx(
                r_ex.tenants[t].rate, rel=0.05)


class TestEngineFactory:
    def test_factory_selects_classes(self):
        from repro.core._sim_reference import (
            ReferenceMultiTenantSimulator, ReferenceSimulator)
        from repro.core.simulator import MultiTenantSimulator
        g = build_random_graph(6, 0.3, 1)
        mt = MultiTenantGraph.union([build_random_graph(5, 0.3, 2)])
        cm = CostModel(ROOMY)
        assert type(make_simulator(g, cm)) is IMCESimulator
        assert type(make_simulator(mt, cm)) is MultiTenantSimulator
        assert type(make_simulator(g, cm, engine="reference")) \
            is ReferenceSimulator
        assert type(make_simulator(mt, cm, engine="reference")) \
            is ReferenceMultiTenantSimulator
        assert make_simulator(g, cm, engine="periodic").mode == "periodic"


class TestContextCaching:
    def test_context_shared_across_simulators(self):
        g = build_random_graph(10, 0.3, 5)
        cm = CostModel(ROOMY)
        s1 = IMCESimulator(g, cm)
        s2 = IMCESimulator(g, cm)
        assert s1._ctx is s2._ctx

    def test_context_invalidated_on_mutation(self):
        from repro.core.graph import OpKind
        g = build_random_graph(10, 0.3, 5)
        cm = CostModel(ROOMY)
        ctx = IMCESimulator(g, cm)._ctx
        g.add("late", OpKind.ADD, deps=[1], out_elems=10.0, out_bytes=10.0)
        assert IMCESimulator(g, cm)._ctx is not ctx

    def test_distinct_profiles_get_distinct_contexts(self):
        g = build_random_graph(10, 0.3, 5)
        fast = CostModel(HardwareProfile(name="fast", t_mvm=50e-9))
        slow = CostModel(HardwareProfile(name="slow", t_mvm=500e-9))
        assert IMCESimulator(g, fast)._ctx is not IMCESimulator(g, slow)._ctx

    def test_mode_validation(self):
        g = build_random_graph(6, 0.3, 2)
        with pytest.raises(ValueError):
            IMCESimulator(g, CostModel(ROOMY), mode="bogus")
