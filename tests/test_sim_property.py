"""Property tests for the compiled simulation core.

The compiled loop (``IMCESimulator._run_streams`` over a precompiled
``SimContext``) must reproduce the frozen reference loop
(``repro.core._sim_reference``) *bit-identically* in the default exact
mode — on random DAGs x random fleets x random schedulers x random
replica configurations, single- and multi-tenant.  The quantized
"periodic" mode (steady-state early exit) must agree with its own full
simulation exactly on the drain-free prefix and with exact mode within
the cost-quantization tolerance.

Deterministic variants run everywhere (jax-free, stdlib-only);
hypothesis widens the sweep when the [test] extra is installed
(``tests/helpers.py`` shims keep collection clean without it).
"""

import pytest

from repro.core import CostModel, make_pus, make_simulator
from repro.core.cost import HardwareProfile
from repro.core.graph import MultiTenantGraph
from repro.core.schedulers import get_scheduler
from repro.core.simulator import IMCESimulator

from helpers import build_random_graph, given, settings, st

ROOMY = HardwareProfile(name="roomy", pu_weight_capacity=1e12)

ALGS = ("lblp", "rr", "wb")


def replicate_some(g, seed: int, max_k: int = 3):
    """Deterministically replicate up to two non-free nodes of ``g``."""
    cands = [n.node_id for n in g.nodes.values() if not n.is_free()]
    if not cands:
        return g
    counts = {}
    for i, nid in enumerate(sorted(cands)):
        if (nid + seed + i) % 3 == 0 and len(counts) < 2:
            counts[nid] = 2 + (nid + seed) % (max_k - 1)
    return g.with_replicas(counts)


def run_both(g, alg: str, n_imc: int, n_dpu: int, frames: int,
             in_flight: int, cm=None):
    cm = cm or CostModel(ROOMY)
    a = get_scheduler(alg, cm).schedule(g, make_pus(n_imc, n_dpu))
    new = make_simulator(g, cm, engine="exact")
    ref = make_simulator(g, cm, engine="reference")
    if isinstance(g, MultiTenantGraph):
        got = new._run_streams(a, frames, in_flight=in_flight)
        exp = ref._run_streams(a, frames, in_flight=in_flight)
    else:
        got = new._simulate(a, frames=frames, in_flight=in_flight)
        exp = ref._simulate(a, frames=frames, in_flight=in_flight)
    return got, exp


class TestExactEquivalence:
    """Compiled exact mode == reference loop, bit for bit."""

    def check(self, g, alg, n_imc, n_dpu, frames=24, in_flight=5):
        got, exp = run_both(g, alg, n_imc, n_dpu, frames, in_flight)
        assert got == exp, (g.name, alg, n_imc, n_dpu)

    def test_random_graphs(self):
        for seed in (0, 1, 7, 23, 99):
            g = build_random_graph(14, 0.3, seed)
            for alg in ALGS:
                self.check(g, alg, 4, 2)

    def test_replicated_random_graphs(self):
        for seed in (2, 5, 11, 42):
            g = replicate_some(build_random_graph(12, 0.35, seed), seed)
            self.check(g, "lblp", 5, 2)

    def test_dynamic_phase_fallback(self):
        """Replica-count lcm beyond MAX_PHASE_PERIOD falls back to
        per-injection activity computation — still bit-identical."""
        from repro.core.simcontext import MAX_PHASE_PERIOD
        g = build_random_graph(10, 0.3, 31, imc_fraction=1.0)
        cands = sorted(n.node_id for n in g.nodes.values() if not n.is_free())
        g2 = g.with_replicas({cands[0]: 5, cands[1]: 13, cands[2]: 7})
        cm = CostModel(ROOMY)
        ctx = IMCESimulator(g2, cm)._ctx
        assert not ctx.phases_compiled  # lcm(5,13,7)=455 > cap
        assert 5 * 13 * 7 > MAX_PHASE_PERIOD
        got, exp = run_both(g2, "lblp", 6, 2, frames=30, in_flight=6)
        assert got == exp

    def test_multi_tenant_union(self):
        mt = MultiTenantGraph.union(
            [build_random_graph(8, 0.3, 3), build_random_graph(10, 0.4, 4)])
        got, exp = run_both(mt, "lblp-mt", 4, 2, frames=16, in_flight=4)
        assert got == exp

    def test_multi_tenant_replicated_union(self):
        mt = MultiTenantGraph.union(
            [build_random_graph(8, 0.3, 6), build_random_graph(9, 0.35, 7)])
        mt = replicate_some(mt, 1)
        got, exp = run_both(mt, "lblp-mt", 5, 3, frames=16, in_flight=4)
        assert got == exp

    def test_open_loop_rates(self):
        cm = CostModel(ROOMY)
        mt = MultiTenantGraph.union(
            [build_random_graph(6, 0.3, 8), build_random_graph(7, 0.3, 9)])
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(4, 2))
        rates = {t: 500.0 + 100 * i for i, t in enumerate(mt.tenants)}
        new = make_simulator(mt, cm, engine="exact")
        ref = make_simulator(mt, cm, engine="reference")
        got = new._run_streams(a, 12, in_flight=0, rates=rates)
        exp = ref._run_streams(a, 12, in_flight=0, rates=rates)
        assert got == exp

    @given(seed=st.integers(0, 5000), n_imc=st.integers(2, 6),
           alg=st.sampled_from(ALGS), in_flight=st.integers(1, 8))
    @settings(max_examples=30, deadline=None)
    def test_property_random(self, seed, n_imc, alg, in_flight):
        g = replicate_some(build_random_graph(12, 0.3, seed), seed)
        got, exp = run_both(g, alg, n_imc, 2, frames=20, in_flight=in_flight)
        assert got == exp


class TestPeriodicMode:
    """Quantized early-exit runs agree with their own full simulation
    exactly (modulo the budget-cut drain tail) and with exact mode
    within the cost-quantization tolerance."""

    def _periodic_pair(self, g, alg, n_imc, n_dpu, frames, in_flight):
        """(early-exit run, full quantized run) over the same schedule."""
        cm = CostModel(ROOMY)
        a = get_scheduler(alg, cm).schedule(g, make_pus(n_imc, n_dpu))
        fast = make_simulator(g, cm, engine="periodic")
        got = fast._simulate(a, frames=frames, in_flight=in_flight)
        fired = fast.last_early_exit
        slow = make_simulator(g, cm, engine="periodic")
        # a fresh context would be shared via the graph cache; disable
        # detection by monkey-free means: raise the arming threshold
        import repro.core.simulator as simmod
        old = simmod._DETECT_MIN_FRAMES
        simmod._DETECT_MIN_FRAMES = frames + 1
        try:
            exp = slow._simulate(a, frames=frames, in_flight=in_flight)
        finally:
            simmod._DETECT_MIN_FRAMES = old
        assert slow.last_early_exit is None
        return got, exp, fired

    def check_periodic(self, g, alg="lblp", n_imc=4, n_dpu=2,
                       frames=96, in_flight=5):
        got, exp, fired = self._periodic_pair(
            g, alg, n_imc, n_dpu, frames, in_flight)
        mk_g, comp_g, busy_g, soj_g = got
        mk_e, comp_e, busy_e, soj_e = exp
        assert len(comp_g) == len(comp_e) == frames
        # the budget cut relaxes contention only for the trailing
        # ~in_flight frames; everything before is exactly periodic
        safe = frames - 2 * in_flight - 4
        assert comp_g[:safe] == comp_e[:safe], (g.name, alg, fired)
        assert soj_g[:safe] == soj_e[:safe], (g.name, alg, fired)
        # aggregate rate agrees tightly even across the tail
        rate_g = (len(comp_g) - 1) / (comp_g[-1] - comp_g[0])
        rate_e = (len(comp_e) - 1) / (comp_e[-1] - comp_e[0])
        assert rate_g == pytest.approx(rate_e, rel=0.05)
        assert sum(e - b for b, e in
                   (iv for ivs in busy_g.values() for iv in ivs)) > 0

    def test_random_graphs_fire_and_agree(self):
        fired_any = False
        for seed in (0, 3, 9, 21):
            g = build_random_graph(12, 0.3, seed)
            got, exp, fired = self._periodic_pair(g, "lblp", 4, 2, 96, 5)
            fired_any = fired_any or fired is not None
            self.check_periodic(g)
        assert fired_any, "steady-state exit never fired on any seed"

    def test_replicated_graphs(self):
        for seed in (4, 13):
            g = replicate_some(build_random_graph(10, 0.35, seed), seed)
            self.check_periodic(g)

    def test_periodic_vs_exact_rate(self):
        """Quantization + steady-state sampling stay within ~5% of the
        exact-mode figures on random workloads (they usually agree to
        <1e-2; the bound here is deliberately loose)."""
        cm = CostModel(ROOMY)
        for seed in (1, 6, 17):
            g = build_random_graph(12, 0.3, seed)
            a = get_scheduler("lblp", cm).schedule(g, make_pus(4, 2))
            r_ex = make_simulator(g, cm, engine="exact").run(a, frames=96)
            r_pe = make_simulator(g, cm, engine="periodic").run(a, frames=96)
            assert r_pe.rate == pytest.approx(r_ex.rate, rel=0.05)
            assert r_pe.latency == pytest.approx(r_ex.latency, rel=0.05)
            assert r_pe.mean_utilization == pytest.approx(
                r_ex.mean_utilization, rel=0.05)

    @given(seed=st.integers(0, 5000), n_imc=st.integers(3, 6),
           in_flight=st.integers(2, 8))
    @settings(max_examples=15, deadline=None)
    def test_property_periodic(self, seed, n_imc, in_flight):
        g = replicate_some(build_random_graph(11, 0.3, seed), seed)
        self.check_periodic(g, n_imc=n_imc, in_flight=in_flight)


def _suppress_detection(frames: int):
    """Context: run the periodic engine with steady-state detection
    disarmed (the full quantized simulation, the oracle of the fast
    path)."""
    import contextlib

    import repro.core.simulator as simmod

    @contextlib.contextmanager
    def ctx():
        old = simmod._DETECT_MIN_FRAMES
        simmod._DETECT_MIN_FRAMES = frames + 1
        try:
            yield
        finally:
            simmod._DETECT_MIN_FRAMES = old

    return ctx()


class TestPeriodicMultiStream:
    """Multi-stream steady-state early exit: the extrapolated run must
    reproduce the never-draining quantized simulation *exactly*, per
    stream, on the quantized grid."""

    def _equal_union(self, seed, n=10, p=0.3):
        return MultiTenantGraph.union(
            [build_random_graph(n, p, seed), build_random_graph(n, p, seed)])

    def _fast_run(self, mt, alg, n_imc, n_dpu, frames, in_flight):
        cm = CostModel(ROOMY)
        a = get_scheduler(alg, cm).schedule(mt, make_pus(n_imc, n_dpu))
        sim = make_simulator(mt, cm, engine="periodic")
        out = sim._run_streams(a, frames, in_flight=in_flight)
        return cm, a, sim, out

    def _full_budgets(self, comps, frames, in_flight):
        """Per-stream budgets so the oracle run never starts draining
        before the fast run's last extrapolated completion."""
        t_end = max(c[-1] for c in comps.values())
        buds = {}
        for k, c in comps.items():
            tail = c[len(c) // 2:]
            iv = max((tail[-1] - tail[0]) / max(len(tail) - 1, 1), 1e-15)
            buds[k] = frames + int((t_end - c[-1]) / iv) + in_flight + 16
        return buds

    def check_multi_stream(self, mt, alg="lblp-mt", n_imc=4, n_dpu=2,
                           frames=64, in_flight=5, require_fire=False):
        cm, a, sim, fast = self._fast_run(mt, alg, n_imc, n_dpu,
                                          frames, in_flight)
        fired = sim.last_early_exit
        if fired is None:
            assert not require_fire, "expected the early exit to fire"
            return None
        _, comps_f, _, soj_f, _ = fast
        buds = self._full_budgets(comps_f, frames, in_flight)
        slow = make_simulator(mt, cm, engine="periodic")
        with _suppress_detection(max(buds.values())):
            _, comps_o, _, soj_o, _ = slow._run_streams(
                a, buds, in_flight=in_flight)
        assert slow.last_early_exit is None

        def frame_times(soj, comps, n):
            # closed loop: frame f is injected at the (f - in_flight)-th
            # completion, so per-frame completion times reconstruct from
            # the frame-indexed sojourns plus the time-ordered completions
            return [soj[f] + (0.0 if f < in_flight else comps[f - in_flight])
                    for f in range(n)]

        for t in mt.tenants:
            assert len(comps_f[t]) == frames
            # bit-identical per-frame sojourns and completion times
            # against the drain-free oracle (sorted completion lists
            # cannot be compared directly: replicas complete slightly
            # out of frame order across the budget boundary)
            assert soj_f[t] == soj_o[t][:frames], (mt.name, t, fired)
            assert frame_times(soj_f[t], comps_f[t], frames) == \
                frame_times(soj_o[t], comps_o[t], frames), (mt.name, t, fired)
        return fired

    def test_equal_tenants_fire_and_match(self):
        fired_any = False
        for seed in (0, 3, 9, 21, 33):
            mt = self._equal_union(seed)
            fired = self.check_multi_stream(mt)
            fired_any = fired_any or fired is not None
        assert fired_any, "no equal-tenant union ever early-exited"

    def test_three_tenants(self):
        for seed in (2, 7):
            g = build_random_graph(9, 0.3, seed)
            mt = MultiTenantGraph.union(
                [g, build_random_graph(9, 0.3, seed),
                 build_random_graph(9, 0.3, seed)])
            self.check_multi_stream(mt, n_imc=5, n_dpu=2, frames=72)

    def test_replicated_multi_stream(self):
        fired_any = False
        for seed in (4, 11, 19):
            mt = replicate_some(self._equal_union(seed, n=9), seed)
            fired = self.check_multi_stream(mt, n_imc=5, n_dpu=2, frames=96,
                                            in_flight=4)
            fired_any = fired_any or fired is not None
        assert fired_any, "no replicated union ever early-exited"

    def test_heterogeneous_tenants(self):
        """Unequal weights: the rationalized virtual-time grid plus
        clamped-gap fingerprints; whether the exit fires depends on the
        transient length, but whenever it fires it must be exact."""
        for seed in (1, 5, 13):
            mt = MultiTenantGraph.union(
                [build_random_graph(8, 0.3, seed),
                 build_random_graph(12, 0.35, seed + 100)])
            self.check_multi_stream(mt, frames=96, in_flight=3)

    def test_aggregates_match_full_sim_same_budget(self):
        """run()-level rates/latencies vs the full quantized simulation
        at the same frame budget (the drain tail the extrapolation
        excludes only perturbs the last in-flight frames)."""
        cm = CostModel(ROOMY)
        mt = self._equal_union(3)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(4, 2))
        fast = make_simulator(mt, cm, engine="periodic")
        r_f = fast.run(a, frames=64)
        assert fast.last_early_exit is not None
        with _suppress_detection(200):
            slow = make_simulator(mt, cm, engine="periodic")
            # the run() memo lives on the shared context: drop it so the
            # oracle actually simulates instead of replaying the fast run
            slow._ctx.memo.clear()
            r_o = slow.run(a, frames=64)
        assert slow.last_early_exit is None
        assert slow.last_events > 0, "oracle run was a memo hit"
        for t in mt.tenants:
            assert r_f.tenants[t].rate == pytest.approx(
                r_o.tenants[t].rate, rel=0.05)
            assert r_f.tenants[t].latency == pytest.approx(
                r_o.tenants[t].latency, rel=0.05)
        assert r_f.rate == pytest.approx(r_o.rate, rel=0.05)

    def test_fingerprint_cap_falls_back_to_full_sim(self, monkeypatch):
        """MAX fingerprint cap reached -> detection disarms and the run
        equals the plain quantized simulation bit for bit."""
        import repro.core.simulator as simmod
        cm = CostModel(ROOMY)
        mt = self._equal_union(9)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(4, 2))
        monkeypatch.setattr(simmod, "_DETECT_MAX_STATES", 0)
        capped = make_simulator(mt, cm, engine="periodic")
        got = capped._run_streams(a, 64, in_flight=5)
        assert capped.last_early_exit is None
        with _suppress_detection(64):
            plain = make_simulator(mt, cm, engine="periodic")
            exp = plain._run_streams(a, 64, in_flight=5)
        assert got == exp

    def test_numpy_free_extrapolation_identical(self, monkeypatch):
        """The scalar fallback must produce bit-identical results to the
        vectorized extrapolation (all quantities are integer-valued).
        ``_VECTOR_MIN`` is forced down so the numpy branches actually
        execute at this frame budget."""
        import repro.core.simulator as simmod
        if simmod._np is None:
            pytest.skip("numpy not installed; scalar path is the only path")
        cm = CostModel(ROOMY)
        mt = self._equal_union(21)
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(4, 2))
        monkeypatch.setattr(simmod, "_VECTOR_MIN", 4)
        with_np = make_simulator(mt, cm, engine="periodic")
        got_np = with_np._run_streams(a, 64, in_flight=5)
        assert with_np.last_early_exit is not None
        monkeypatch.setattr(simmod, "_np", None)
        scalar = make_simulator(mt, cm, engine="periodic")
        got_py = scalar._run_streams(a, 64, in_flight=5)
        assert with_np.last_early_exit == scalar.last_early_exit
        assert got_np == got_py

    def test_quantized_weights_properties(self):
        from repro.core.simcontext import quantize_stream_weights
        ws = quantize_stream_weights([1.05e-3, 1.83e-3], 64)
        assert ws is not None
        assert all(w == int(w) and w >= 1 for w in ws)
        # ratio error bounded by the rationalization denominator cap
        assert abs(ws[1] / ws[0] - 1.83e-3 / 1.05e-3) / (1.83 / 1.05) < 0.04
        assert quantize_stream_weights([1.0, 0.0], 64) is None
        assert quantize_stream_weights([1.0, 1e9], 10**9) is None  # overflow
        assert quantize_stream_weights([2.0, 2.0, 1.0], 64) == [2.0, 2.0, 1.0]


class TestPhaseTableDelta:
    """The delta-built replica phase tables must be content-identical to
    the straightforward per-phase recomputation."""

    @staticmethod
    def _naive_tables(ctx):
        P = ctx.phase_period
        succs_by_phase = [
            tuple(tuple(k for k in ctx.succs[j] if ctx.active(k, ph))
                  for j in range(ctx.n))
            for ph in range(P)
        ]
        base_missing, init_ready, phase_sinks = [], [], []
        for s, _ in enumerate(ctx.stream_keys):
            miss_by_phase, ready_by_phase, sinks_by_phase = [], [], []
            for ph in range(P):
                miss = [0] * ctx.n
                ready = []
                sinks = 0
                for j in ctx.members[s]:
                    if not ctx.active(j, ph):
                        continue
                    miss[j] = sum(1 for p in ctx.preds[j] if ctx.active(p, ph))
                    if not any(ctx.active(k, ph) for k in ctx.succs[j]):
                        sinks += 1
                    if miss[j] == 0:
                        ready.append(j)
                miss_by_phase.append(miss)
                ready_by_phase.append(ready)
                sinks_by_phase.append(sinks)
            base_missing.append(miss_by_phase)
            init_ready.append(ready_by_phase)
            phase_sinks.append(sinks_by_phase)
        return succs_by_phase, base_missing, init_ready, phase_sinks

    def test_delta_equals_naive(self):
        for seed in (2, 5, 11, 42):
            g = replicate_some(build_random_graph(12, 0.35, seed), seed)
            ctx = IMCESimulator(g, CostModel(ROOMY))._ctx
            if not ctx.replicated:
                continue
            succs, miss, ready, sinks = self._naive_tables(ctx)
            assert [tuple(r) for r in ctx.succs_by_phase] == \
                [tuple(r) for r in succs]
            assert ctx.base_missing == miss
            assert ctx.init_ready == ready
            assert ctx.phase_sinks == sinks
            # digests encode exactly the missing rows
            pw = ctx.digest_pow
            for s in range(len(ctx.stream_keys)):
                for ph in range(ctx.phase_period):
                    dig = sum(miss[s][ph][j] * pw[j] for j in range(ctx.n))
                    assert ctx.base_digest[s][ph] == dig

    def test_mt_replicated_delta(self):
        mt = MultiTenantGraph.union(
            [build_random_graph(8, 0.3, 6), build_random_graph(9, 0.35, 7)])
        mt = replicate_some(mt, 1)
        from repro.core.simulator import MultiTenantSimulator
        ctx = MultiTenantSimulator(mt, CostModel(ROOMY))._ctx
        if ctx.replicated:
            succs, miss, ready, sinks = self._naive_tables(ctx)
            assert ctx.base_missing == miss
            assert ctx.init_ready == ready
            assert ctx.phase_sinks == sinks


class TestSeededContexts:
    """Replica-variant contexts seeded from the base graph's must equal
    a from-scratch build bit for bit."""

    def test_seeded_equals_scratch(self):
        from repro.core.simcontext import SimContext
        cm = CostModel(ROOMY)
        g = build_random_graph(12, 0.35, 8)
        base_sim = IMCESimulator(g, cm)       # caches the base context
        cands = sorted(n.node_id for n in g.nodes.values() if not n.is_free())
        g_v = g.with_replicas({cands[0]: 3, cands[1]: 2})
        assert g_v.ctx_seed() is g
        seeded = IMCESimulator(g_v, cm)._ctx
        assert seeded._seed is base_sim._ctx
        scratch = SimContext(g_v, cm, IMCESimulator(g_v, cm)._stream_structure())
        assert seeded.blevel_by_id == scratch.blevel_by_id
        assert seeded.negbl == scratch.negbl
        assert seeded.xfer_cross == scratch.xfer_cross
        from repro.core.graph import PUType
        for quant in (False, True):
            assert seeded.exec_table(PUType.IMC, 1.0, quant) == \
                scratch.exec_table(PUType.IMC, 1.0, quant)
            assert seeded.exec_table(PUType.DPU, 1.0, quant) == \
                scratch.exec_table(PUType.DPU, 1.0, quant)
            assert seeded.xfer_table(quant) == scratch.xfer_table(quant)

    def test_mutation_voids_seed(self):
        g = build_random_graph(8, 0.3, 4)
        g_v = g.copy()
        assert g_v.ctx_seed() is g
        from repro.core.graph import OpKind
        g_v.add("late", OpKind.ADD, deps=[1], out_elems=4.0, out_bytes=4.0)
        assert g_v.ctx_seed() is None

    def test_probe_session_reuses_variants(self):
        from repro.core.schedulers.lblp_r import LBLPRScheduler
        cm = CostModel(ROOMY)
        g = build_random_graph(12, 0.3, 15)
        pus = make_pus(5, 2)
        a1 = LBLPRScheduler(cm, replica_budget=2).schedule(g, pus)
        sess = list(g.scratch().values())
        assert sess, "probe session not cached on the base graph"
        a2 = LBLPRScheduler(cm, replica_budget=4).schedule(g, pus)
        # the budget-2 prefix of the budget-4 sweep came from the cache,
        # and equal replica signatures share one derived graph object
        if a1.meta["replicas"] == a2.meta["replicas"]:
            assert a1.meta["replicated_graph"] is a2.meta["replicated_graph"]
        assert a1.mapping == {**a1.mapping}  # sanity


class TestPeriodicMultiTenant:
    def test_open_loop_rates_quantized_grid(self):
        """Open-loop injection times must live on the tick grid too:
        a periodic-mode rates run has to reproduce the requested
        per-tenant rates, not a ticks/seconds unit mix."""
        cm = CostModel(ROOMY)
        mt = MultiTenantGraph.union(
            [build_random_graph(6, 0.3, 22), build_random_graph(7, 0.3, 23)])
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(4, 2))
        rates = {t: 40.0 + 10 * i for i, t in enumerate(mt.tenants)}
        r_ex = make_simulator(mt, cm, engine="exact").run(
            a, frames=32, rates=rates)
        r_pe = make_simulator(mt, cm, engine="periodic").run(
            a, frames=32, rates=rates)
        assert r_pe.makespan == pytest.approx(r_ex.makespan, rel=1e-6)
        for t in mt.tenants:
            assert r_pe.tenants[t].rate == pytest.approx(
                r_ex.tenants[t].rate, rel=1e-3)
            assert r_pe.tenants[t].latency == pytest.approx(
                r_ex.tenants[t].latency, rel=1e-3)

    def test_mt_periodic_close_to_exact(self):
        """Multi-stream periodic runs (whether or not the steady-state
        exit fires — it depends on the transient length) stay close to
        exact mode despite the cost and weight quantization."""
        cm = CostModel(ROOMY)
        mt = MultiTenantGraph.union(
            [build_random_graph(8, 0.3, 12), build_random_graph(9, 0.3, 13)])
        a = get_scheduler("lblp-mt", cm).schedule(mt, make_pus(5, 3))
        r_ex = make_simulator(mt, cm, engine="exact").run(a, frames=48)
        pe = make_simulator(mt, cm, engine="periodic")
        r_pe = pe.run(a, frames=48)
        assert r_pe.rate == pytest.approx(r_ex.rate, rel=0.05)
        for t in mt.tenants:
            assert r_pe.tenants[t].rate == pytest.approx(
                r_ex.tenants[t].rate, rel=0.05)


class TestEngineFactory:
    def test_factory_selects_classes(self):
        from repro.core._sim_reference import (
            ReferenceMultiTenantSimulator, ReferenceSimulator)
        from repro.core.simulator import MultiTenantSimulator
        g = build_random_graph(6, 0.3, 1)
        mt = MultiTenantGraph.union([build_random_graph(5, 0.3, 2)])
        cm = CostModel(ROOMY)
        assert type(make_simulator(g, cm)) is IMCESimulator
        assert type(make_simulator(mt, cm)) is MultiTenantSimulator
        assert type(make_simulator(g, cm, engine="reference")) \
            is ReferenceSimulator
        assert type(make_simulator(mt, cm, engine="reference")) \
            is ReferenceMultiTenantSimulator
        assert make_simulator(g, cm, engine="periodic").mode == "periodic"


class TestContextCaching:
    def test_context_shared_across_simulators(self):
        g = build_random_graph(10, 0.3, 5)
        cm = CostModel(ROOMY)
        s1 = IMCESimulator(g, cm)
        s2 = IMCESimulator(g, cm)
        assert s1._ctx is s2._ctx

    def test_context_invalidated_on_mutation(self):
        from repro.core.graph import OpKind
        g = build_random_graph(10, 0.3, 5)
        cm = CostModel(ROOMY)
        ctx = IMCESimulator(g, cm)._ctx
        g.add("late", OpKind.ADD, deps=[1], out_elems=10.0, out_bytes=10.0)
        assert IMCESimulator(g, cm)._ctx is not ctx

    def test_distinct_profiles_get_distinct_contexts(self):
        g = build_random_graph(10, 0.3, 5)
        fast = CostModel(HardwareProfile(name="fast", t_mvm=50e-9))
        slow = CostModel(HardwareProfile(name="slow", t_mvm=500e-9))
        assert IMCESimulator(g, fast)._ctx is not IMCESimulator(g, slow)._ctx

    def test_mode_validation(self):
        g = build_random_graph(6, 0.3, 2)
        with pytest.raises(ValueError):
            IMCESimulator(g, CostModel(ROOMY), mode="bogus")
