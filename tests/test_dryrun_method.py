"""Dry-run machinery tests that run on the host (1 CPU device):
sharding-rule invariants, batch/cache spec coverage, collective parsing,
shape-skip rules, and the E/B cost-decomposition identity on a toy config.

The full 512-device sweep runs via ``python -m repro.launch.dryrun``
(results in artifacts/dryrun); these tests validate the *method*.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.configs import SHAPES, all_archs, get_config
from repro.configs.base import shape_supported
from repro.launch.dryrun import parse_collectives
from repro.models.lm import model, sharding


def host_mesh():
    dev = jax.devices()[0]
    import numpy as np
    return Mesh(np.array([[dev]]), ("data", "model"))


class TestShardingRules:
    @pytest.mark.parametrize("arch", all_archs())
    def test_param_specs_cover_tree_and_divide(self, arch):
        """Every param gets a spec whose axes divide its dims on the
        production mesh geometry (validated arithmetically — no devices
        needed)."""
        cfg = get_config(arch)
        aparams = model.abstract_params(cfg)

        class FakeMesh:
            shape = {"data": 16, "model": 16}

        flat = jax.tree_util.tree_flatten_with_path(aparams)[0]
        for path, leaf in flat:
            spec = sharding.param_pspec(cfg, FakeMesh, path, leaf)
            assert len(spec) <= len(leaf.shape)
            for dim, axes in zip(leaf.shape, spec):
                if axes is None:
                    continue
                size = 1
                for a in (axes if isinstance(axes, tuple) else (axes,)):
                    size *= FakeMesh.shape[a]
                assert dim % size == 0, (arch, path, leaf.shape, spec)

    def test_stacked_layer_dim_never_sharded(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        aparams = model.abstract_params(cfg)

        class FakeMesh:
            shape = {"data": 16, "model": 16}

        flat = jax.tree_util.tree_flatten_with_path(aparams)[0]
        for path, leaf in flat:
            names = sharding._path_names(path)
            if "segments" in names and leaf.ndim >= 2:
                spec = sharding.param_pspec(cfg, FakeMesh, path, leaf)
                assert spec[0] is None, (names, spec)

    @pytest.mark.parametrize("arch", all_archs())
    def test_batch_and_cache_specs_exist(self, arch):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = shape_supported(cfg, shape)
            if not ok:
                continue
            spec = model.make_batch_spec(cfg, shape)
            assert spec, (arch, shape.name)
            if shape.mode == "decode":
                cache = model.init_cache_spec(cfg, shape)
                assert len(cache.entries) == len(cfg.segments)


class TestSkipRules:
    def test_long_500k_skips(self):
        expected_run = {"falcon-mamba-7b", "gemma3-1b", "gemma2-27b",
                        "recurrentgemma-9b"}
        runs = {a for a in all_archs()
                if shape_supported(get_config(a), SHAPES["long_500k"])[0]}
        assert runs == expected_run

    def test_full_grid_is_40_cells(self):
        assert len(all_archs()) * len(SHAPES) == 40


class TestCollectiveParse:
    def test_parses_kinds_and_bytes(self):
        hlo = """
          %ar = bf16[8,128] all-reduce(%x), replica_groups={}
          %ag.1 = f32[16,16]{1,0} all-gather(%y), dimensions={0}
          %rs = f32[4] reduce-scatter(%z), dimensions={0}
          %a2a = bf16[2,2] all-to-all(%w)
          %cp = u32[7] collective-permute(%v)
          %ars = bf16[8,128] all-reduce-start(%x)
        """
        got = parse_collectives(hlo)
        assert got["all-reduce"] == 8 * 128 * 2 * 2   # ar + ar-start
        assert got["all-gather"] == 16 * 16 * 4
        assert got["reduce-scatter"] == 16
        assert got["all-to-all"] == 8
        assert got["collective-permute"] == 28

    def test_ignores_non_collectives(self):
        assert parse_collectives("%d = f32[4,4] dot(%a, %b)") == {}


class TestTrainStepMicrobatching:
    def test_grad_accum_matches_single_batch(self):
        """n_mb>1 accumulation == one big batch (same data), to fp tol."""
        cfg = get_config("stablelm-1.6b").smoke()
        cfg = dataclasses.replace(cfg, microbatch=4, remat=False)
        key = jax.random.PRNGKey(0)
        from repro.models.lm import transformer
        from repro.optim import adamw
        params = transformer.init_params(cfg, key)
        from repro.configs.base import ShapeSpec
        batch = model.synth_batch(cfg, ShapeSpec("x", 16, 8, "train"), key)

        one = model.make_train_step(cfg, microbatch=8)   # n_mb = 1
        acc = model.make_train_step(cfg, microbatch=4)   # n_mb = 2
        opt = adamw.init(params)
        p1, _, m1 = jax.jit(one)(params, opt, batch)
        p2, _, m2 = jax.jit(acc)(params, opt, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            p1, p2)
        assert max(jax.tree_util.tree_leaves(d)) < 5e-2
