"""Component-level LM tests: MoE capacity-vs-dense equivalence, SSM scan
vs sequential recurrence, RG-LRU scan, attention masking variants,
optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.models.lm import attention, moe, rglru, ssm
from repro.optim import adamw


class TestMoE:
    @given(st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_capacity_matches_dense_when_no_drops(self, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        B, S, D, F, E, K = 2, 16, 32, 64, 4, 2
        p = moe.init(k1, D, F, E, dtype=jnp.float32)
        x = jax.random.normal(k2, (B, S, D), jnp.float32)
        # capacity_factor huge -> nothing drops -> must equal dense oracle
        got = moe.forward(p, x, K, capacity_factor=float(E) / K * 2)
        ref = moe.dense_forward(p, x, K)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_capacity_drops_bounded(self):
        """With cf=1.0 some tokens drop; output stays finite and close-ish
        to dense (drops only reduce, never corrupt)."""
        key = jax.random.PRNGKey(0)
        p = moe.init(key, 16, 32, 4, dtype=jnp.float32)
        x = jax.random.normal(key, (2, 32, 16), jnp.float32)
        y = moe.forward(p, x, 2, capacity_factor=1.0)
        assert jnp.isfinite(y).all()

    def test_router_normalized(self):
        key = jax.random.PRNGKey(0)
        p = moe.init(key, 16, 32, 8)
        x = jax.random.normal(key, (1, 8, 16))
        gates, idx = moe.route(p, x, 3)
        np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
        assert int(idx.max()) < 8


class TestSSM:
    def test_scan_matches_sequential(self):
        """Associative scan == step-by-step recurrence."""
        key = jax.random.PRNGKey(0)
        B, S, Di, N = 2, 24, 8, 4
        dt = jax.nn.softplus(jax.random.normal(key, (B, S, Di)))
        bmat = jax.random.normal(jax.random.PRNGKey(1), (B, S, N))
        cmat = jax.random.normal(jax.random.PRNGKey(2), (B, S, N))
        xin = jax.random.normal(jax.random.PRNGKey(3), (B, S, Di))
        a_log = jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                 (Di, 1)))
        y, h_last = ssm._ssm_scan(dt, bmat, cmat, xin, a_log)
        # sequential oracle
        A = -jnp.exp(a_log)
        h = jnp.zeros((B, Di, N))
        ys = []
        for t in range(S):
            g = jnp.exp(dt[:, t, :, None] * A)
            u = (dt[:, t] * xin[:, t])[:, :, None] * bmat[:, t, None, :]
            h = g * h + u
            ys.append(jnp.einsum("bdn,bn->bd", h, cmat[:, t]))
        ref = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_prefill_then_decode_matches_full(self):
        """Running S tokens at once == prefill S-1 then decode 1."""
        key = jax.random.PRNGKey(0)
        D, Di, N, R = 16, 32, 4, 8
        p = ssm.init(key, D, Di, N, R, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, D), jnp.float32)
        full, _ = ssm.forward(p, x)
        part, state = ssm.forward(p, x[:, :-1])
        last, _ = ssm.decode_step(p, x[:, -1:], state)
        np.testing.assert_allclose(np.asarray(last[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=2e-3, atol=2e-3)


class TestRGLRU:
    def test_prefill_then_decode_matches_full(self):
        key = jax.random.PRNGKey(0)
        D, Di = 16, 32
        p = rglru.init(key, D, Di, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, D), jnp.float32)
        full, _ = rglru.forward(p, x)
        part, state = rglru.forward(p, x[:, :-1])
        last, _ = rglru.forward(p, x[:, -1:], state)
        np.testing.assert_allclose(np.asarray(last[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=2e-3, atol=2e-3)

    def test_state_decay_bounded(self):
        """|h| stays bounded (the sqrt(1-a^2) normalization)."""
        key = jax.random.PRNGKey(0)
        p = rglru.init(key, 8, 16, dtype=jnp.float32)
        x = jax.random.normal(key, (1, 256, 8), jnp.float32)
        _, st = rglru.forward(p, x)
        assert float(jnp.max(jnp.abs(st.h))) < 50.0


class TestAttention:
    def _mk(self, key, d=32, h=4, kv=2, hd=8):
        return attention.init(key, d, h, kv, hd, dtype=jnp.float32)

    def test_causality(self):
        """Future tokens must not affect earlier positions."""
        key = jax.random.PRNGKey(0)
        p = self._mk(key)
        x = jax.random.normal(key, (1, 8, 32), jnp.float32)
        pos = jnp.arange(8)
        y1 = attention.forward(p, x, pos)
        x2 = x.at[:, -1].set(99.0)
        y2 = attention.forward(p, x2, pos)
        np.testing.assert_allclose(np.asarray(y1[:, :-1]),
                                   np.asarray(y2[:, :-1]), rtol=1e-5)

    def test_sliding_window_blocks_far_tokens(self):
        key = jax.random.PRNGKey(0)
        p = self._mk(key)
        x = jax.random.normal(key, (1, 16, 32), jnp.float32)
        pos = jnp.arange(16)
        yw = attention.forward(p, x, pos, window=jnp.int32(4))
        x2 = x.at[:, 0].set(77.0)   # outside every window>=5 position
        yw2 = attention.forward(p, x2, pos, window=jnp.int32(4))
        np.testing.assert_allclose(np.asarray(yw[:, 8:]),
                                   np.asarray(yw2[:, 8:]), rtol=1e-5)

    def test_softcap_bounds_logits_effect(self):
        key = jax.random.PRNGKey(0)
        p = self._mk(key)
        x = 100.0 * jax.random.normal(key, (1, 8, 32), jnp.float32)
        pos = jnp.arange(8)
        y = attention.forward(p, x, pos, softcap=5.0)
        assert jnp.isfinite(y).all()

    def test_gqa_expand(self):
        k = jnp.arange(2 * 3 * 2 * 4, dtype=jnp.float32).reshape(2, 3, 2, 4)
        out = attention._expand_kv(k, 6)
        assert out.shape == (2, 3, 6, 4)
        np.testing.assert_array_equal(np.asarray(out[:, :, 0]),
                                      np.asarray(out[:, :, 1]))

    def test_decode_step_matches_forward(self):
        key = jax.random.PRNGKey(3)
        p = self._mk(key)
        S = 9
        x = jax.random.normal(key, (2, S, 32), jnp.float32)
        pos = jnp.arange(S)
        full = attention.forward(p, x, pos)
        cache = attention.prefill(p, x[:, :-1], pos[:-1], s_max=16)
        y, _ = attention.decode_step(p, x[:, -1:], cache,
                                     jnp.asarray(S - 1, jnp.int32))
        np.testing.assert_allclose(np.asarray(y[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=2e-4, atol=2e-4)


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                                weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)

        @jax.jit
        def step(params, state):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            return adamw.apply(cfg, params, state, grads)

        for _ in range(200):
            params, state, m = step(params, state)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.05

    def test_grad_clip(self):
        cfg = adamw.AdamWConfig(grad_clip=1.0)
        params = {"w": jnp.zeros(3)}
        state = adamw.init(params)
        grads = {"w": jnp.array([1e6, 0.0, 0.0])}
        _, _, m = adamw.apply(cfg, params, state, grads)
        assert float(m["grad_norm"]) > 1e5   # norm reported pre-clip

    def test_schedule_shape(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
               (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert 0.1 < lrs[3] < 1.0
        assert lrs[4] == pytest.approx(0.1, abs=1e-6)
