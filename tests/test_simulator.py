"""DES simulator invariants: latency bounds, steady-state rate vs the
analytic pipeline bound, utilization sanity, determinism."""


import pytest
from helpers import given, settings, st

from repro.core.cost import CostModel, HardwareProfile, make_pus
from repro.core.graph import Graph, OpKind
from repro.core.schedulers import get_scheduler
from repro.core.simulator import IMCESimulator

from helpers import build_random_graph, random_graph_st

ROOMY = HardwareProfile(name="roomy", pu_weight_capacity=1e12)


def chain_graph(n: int, n_vectors: int = 256) -> Graph:
    g = Graph("chain")
    prev = None
    for i in range(n):
        node = g.add(f"c{i}", OpKind.CONV, flops=1e6, weight_bytes=1e3,
                     out_bytes=2e3, out_elems=2e3,
                     meta=dict(cin_kk=64, cout=64, n_vectors=n_vectors))
        if prev is not None:
            g.add_edge(prev, node.node_id)
        prev = node.node_id
    return g


class TestAnalyticAgreement:
    def test_single_pu_latency_equals_sum(self):
        g = chain_graph(5)
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp", cm).schedule(g, make_pus(1, 0))
        sim = IMCESimulator(g, cm)
        lat = sim.latency_only(a)
        expected = sum(cm.time(n) for n in g.nodes.values())
        assert lat == pytest.approx(expected, rel=1e-9)

    def test_chain_rate_reaches_pipeline_bound(self):
        """A chain split over k PUs streams at 1/max_stage_time (+ transfer
        overlap), so measured interval ~ bound within transfer slack."""
        g = chain_graph(6)
        cm = CostModel(ROOMY)
        fleet = make_pus(3, 0)
        a = get_scheduler("lblp", cm).schedule(g, fleet)
        r = IMCESimulator(g, cm).run(a, frames=256)
        # one-sided 2% tolerance: the window estimator has O(1/frames)
        # burst-phase bias (see simulator._steady_state)
        assert r.interval >= r.bound_interval * 0.98
        # transfers are DMA-overlapped; steady interval should be close
        assert r.interval <= r.bound_interval * 1.25

    @given(g=random_graph_st, n_imc=st.integers(1, 4))
    @settings(max_examples=40, deadline=None)
    def test_interval_never_beats_bound(self, g, n_imc):
        cm = CostModel(ROOMY)
        fleet = make_pus(n_imc, 2)
        a = get_scheduler("lblp", cm).schedule(g, fleet)
        r = IMCESimulator(g, cm).run(a, frames=128)
        assert r.interval >= r.bound_interval * 0.95

    @given(g=random_graph_st)
    @settings(max_examples=40, deadline=None)
    def test_latency_at_least_critical_path(self, g):
        cm = CostModel(ROOMY)
        fleet = make_pus(3, 2)
        a = get_scheduler("lblp", cm).schedule(g, fleet)
        lat = IMCESimulator(g, cm).latency_only(a)
        crit = g.critical_time(lambda n: cm.time(n))
        assert lat >= crit * (1 - 1e-9)

    @given(g=random_graph_st)
    @settings(max_examples=30, deadline=None)
    def test_utilization_in_unit_interval(self, g):
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp", cm).schedule(g, make_pus(2, 2))
        r = IMCESimulator(g, cm).run(a, frames=48)
        for u in r.utilization.values():
            assert -1e-9 <= u <= 1.0 + 1e-9
        assert 0.0 <= r.mean_utilization <= 1.0 + 1e-9


class TestBehaviour:
    def test_determinism(self):
        g = build_random_graph(18, 0.3, seed=5)
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp", cm).schedule(g, make_pus(3, 2))
        r1 = IMCESimulator(g, cm).run(a, frames=40)
        r2 = IMCESimulator(g, cm).run(a, frames=40)
        assert r1.latency == r2.latency
        assert r1.interval == r2.interval
        assert r1.busy == r2.busy

    def test_more_pus_never_slower_chain(self):
        """On a chain, rate with k+1 PUs >= rate with k PUs (monotone
        pipeline speedup), latency roughly flat."""
        g = chain_graph(8)
        cm = CostModel(ROOMY)
        rates = []
        for k in (1, 2, 4, 8):
            a = get_scheduler("lblp", cm).schedule(g, make_pus(k, 0))
            rates.append(IMCESimulator(g, cm).run(a, frames=48).rate)
        assert all(b >= a * (1 - 1e-6) for a, b in zip(rates, rates[1:]))

    def test_parallel_branches_exploit_parallelism(self):
        """Two independent heavy branches on 2 PUs should give latency
        close to one branch, not the sum."""
        g = Graph()
        src = g.add("in", OpKind.INPUT)
        meta = dict(cin_kk=512, cout=512, n_vectors=2048)
        b1 = g.add("b1", OpKind.CONV, deps=[src.node_id], flops=1e8,
                   weight_bytes=1e3, out_bytes=1e3, out_elems=1e3, meta=meta)
        b2 = g.add("b2", OpKind.CONV, deps=[src.node_id], flops=1e8,
                   weight_bytes=1e3, out_bytes=1e3, out_elems=1e3, meta=meta)
        join = g.add("add", OpKind.ADD, deps=[b1.node_id, b2.node_id],
                     out_bytes=1e3, out_elems=1e3)
        cm = CostModel(ROOMY)
        a = get_scheduler("lblp", cm).schedule(g, make_pus(2, 1))
        # branch constraint must separate b1/b2
        assert a.mapping[b1.node_id] != a.mapping[b2.node_id]
        lat = IMCESimulator(g, cm).latency_only(a)
        t_branch = cm.time(g.nodes[b1.node_id])
        assert lat < 1.6 * t_branch  # far below 2x

    def test_transfer_cost_charged_cross_pu_only(self):
        g = chain_graph(2)
        prof = HardwareProfile(pu_weight_capacity=1e12, dram_bw=1e6, t_ipi=1e-3)
        cm = CostModel(prof)
        # same PU: no transfer
        a1 = get_scheduler("lblp", cm).schedule(g, make_pus(1, 0))
        lat1 = IMCESimulator(g, cm).latency_only(a1)
        # two PUs: one transfer of 2KB at 1MB/s + 1ms IPI ~ 3ms extra
        a2 = get_scheduler("rr", cm).schedule(g, make_pus(2, 0))
        assert a2.mapping[1] != a2.mapping[2]
        lat2 = IMCESimulator(g, cm).latency_only(a2)
        assert lat2 - lat1 == pytest.approx(2e3 / 1e6 + 1e-3, rel=1e-6)
