"""Scheduler unit + property tests: completeness, compatibility, capacity,
load-balance quality, determinism."""

import math

import pytest
from helpers import given, settings, st

from repro.core.cost import CostModel, HardwareProfile, make_pus
from repro.core.graph import Graph, OpKind, PUType
from repro.core.schedulers import available, get_scheduler
from repro.core.schedulers.base import ScheduleError
from repro.core.schedulers.lblp import LBLPScheduler
from repro.core.schedulers.optimal import OptimalScheduler

from helpers import build_random_graph, random_graph_st

PAPER_ALGS = ["lblp", "wb", "rr", "rd"]
ALL_ALGS = [a for a in available() if a != "optimal"]

#: profile with generous capacity so random graphs always fit
ROOMY = HardwareProfile(name="roomy", pu_weight_capacity=1e12)


def fleet_st():
    return st.tuples(st.integers(1, 6), st.integers(1, 3)).map(
        lambda t: make_pus(*t)
    )


class TestAllSchedulers:
    @given(g=random_graph_st, fleet=fleet_st(),
           alg=st.sampled_from(ALL_ALGS))
    @settings(max_examples=80, deadline=None)
    def test_schedule_is_complete_and_valid(self, g, fleet, alg):
        cm = CostModel(ROOMY)
        a = get_scheduler(alg, cm).schedule(g, fleet)
        a.validate(g, cm, check_capacity=False)
        # every schedulable node mapped exactly once to a compatible PU
        for node in g.nodes.values():
            if node.is_free():
                continue
            pu = a.pu_by_id(a.mapping[node.node_id])
            assert not math.isinf(cm.time(node, pu.pu_type, pu.speed))

    @given(g=random_graph_st, fleet=fleet_st())
    @settings(max_examples=40, deadline=None)
    def test_determinism(self, g, fleet):
        cm = CostModel(ROOMY)
        for alg in ALL_ALGS:
            m1 = get_scheduler(alg, cm).schedule(g, fleet).mapping
            m2 = get_scheduler(alg, cm).schedule(g, fleet).mapping
            assert m1 == m2, alg


class TestLBLP:
    def test_respects_capacity_when_feasible(self):
        g = Graph()
        for i in range(4):
            g.add(f"c{i}", OpKind.CONV, flops=1e6, weight_bytes=400e3,
                  out_bytes=1e3, out_elems=1e3,
                  meta=dict(cin_kk=64, cout=64, n_vectors=64))
        for i in range(1, 4):
            g.add_edge(i, i + 1)
        prof = HardwareProfile(pu_weight_capacity=800e3)
        cm = CostModel(prof)
        pus = make_pus(2, 1, prof)
        a = LBLPScheduler(cm).schedule(g, pus)
        a.validate(g, cm, check_capacity=True)
        w = a.weights(g)
        assert all(v <= 800e3 * 1.001 for v in w.values())

    def test_spill_waiver_when_infeasible(self):
        g = Graph()
        g.add("huge", OpKind.CONV, flops=1e6, weight_bytes=5e6,
              out_bytes=1e3, out_elems=1e3,
              meta=dict(cin_kk=64, cout=64, n_vectors=64))
        prof = HardwareProfile(pu_weight_capacity=700e3)
        cm = CostModel(prof)
        a = LBLPScheduler(cm).schedule(g, make_pus(1, 1, prof))
        assert a.meta["capacity_spills"] == [1]
        with pytest.raises(ScheduleError):
            a.validate(g, cm, check_capacity=True)

    def test_spill_regression_records_and_assigns_every_node(self):
        """Pins the capacity-spill contract: when the fleet cannot hold a
        node, LBLP waives capacity (the emulator spills to DRAM), records
        the node id in meta["capacity_spills"], and STILL assigns it —
        the mapping stays complete, and nodes that do fit never spill."""
        g = Graph()
        prev = None
        # 3 oversize nodes (spill) interleaved with 3 that fit
        for i, w in enumerate([5e6, 10e3, 5e6, 10e3, 5e6, 10e3]):
            n = g.add(f"c{i}", OpKind.CONV, flops=1e6, weight_bytes=w,
                      out_bytes=1e3, out_elems=1e3,
                      meta=dict(cin_kk=64, cout=64, n_vectors=64))
            if prev is not None:
                g.add_edge(prev, n.node_id)
            prev = n.node_id
        prof = HardwareProfile(pu_weight_capacity=700e3)
        cm = CostModel(prof)
        a = LBLPScheduler(cm).schedule(g, make_pus(2, 1, prof))
        assert sorted(a.meta["capacity_spills"]) == [1, 3, 5]
        assert set(a.mapping) == set(g.nodes)  # waiver still assigns
        # waived nodes still land on a type-compatible PU
        for nid in (1, 3, 5):
            pu = a.pu_by_id(a.mapping[nid])
            assert pu.pu_type == PUType.IMC
        a.validate(g, cm, check_capacity=False)

    @given(seed=st.integers(0, 500), n=st.integers(4, 20),
           n_imc=st.integers(1, 5))
    @settings(max_examples=60, deadline=None)
    def test_greedy_quality_bound_vs_optimal(self, seed, n, n_imc):
        """Without the branch constraint, LBLP is greedy min-load list
        scheduling per PU type (LP nodes first, so not global LPT); the
        general Graham list bound applies: bottleneck <= (2 - 1/m) * OPT."""
        g = build_random_graph(n, 0.25, seed)
        cm = CostModel(ROOMY)
        fleet = make_pus(n_imc, 2)
        lblp = LBLPScheduler(cm, branch_constraint=False).schedule(g, fleet)
        opt = OptimalScheduler(cm).schedule(g, fleet)
        b_lblp = lblp.bottleneck(g, cm)
        b_opt = opt.bottleneck(g, cm)
        m = max(n_imc, 2)
        assert b_opt <= b_lblp * (1 + 1e-9)
        assert b_lblp <= (2.0 - 1.0 / m) * b_opt * (1 + 1e-9)

    @given(g=random_graph_st)
    @settings(max_examples=30, deadline=None)
    def test_longest_path_nodes_spread(self, g):
        """LP nodes of the same type land on distinct PUs while PUs remain
        emptier than LP nodes (LPT property: each new min-load PU is empty
        until all PUs have one node)."""
        cm = CostModel(ROOMY)
        fleet = make_pus(4, 2)
        a = LBLPScheduler(cm).schedule(g, fleet)
        lp = a.meta["longest_path"]
        for pu_type, n_pus in ((PUType.IMC, 4), (PUType.DPU, 2)):
            typed = [n for n in lp
                     if not g.nodes[n].is_free() and g.nodes[n].pu_type == pu_type]
            k = min(len(typed), n_pus)
            # the k largest typed LP nodes must be on k distinct PUs
            typed.sort(key=lambda n: -cm.time(g.nodes[n]))
            assert len({a.mapping[n] for n in typed[:k]}) == k


class TestWB:
    @given(g=random_graph_st)
    @settings(max_examples=30, deadline=None)
    def test_weight_balance_property(self, g):
        """WB's invariant: moving any single IMC node from its PU to any
        other IMC PU cannot have been better *at assignment time* — we
        check the weaker global property that the most-loaded (by weights)
        PU holds no node that would fit strictly better elsewhere at the
        end state minus itself (standard greedy post-condition)."""
        cm = CostModel(ROOMY)
        fleet = make_pus(3, 1)
        a = get_scheduler("wb", cm).schedule(g, fleet)
        w = a.weights(g)
        imc_ids = [p.pu_id for p in fleet if p.pu_type == PUType.IMC]
        heaviest = max(imc_ids, key=lambda p: w[p])
        for nid in a.nodes_on(heaviest):
            node = g.nodes[nid]
            if node.pu_type != PUType.IMC:
                continue
            for other in imc_ids:
                if other == heaviest:
                    continue
                # moving the node must not strictly reduce the max weight
                new_max = max(w[heaviest] - node.weight_bytes,
                              w[other] + node.weight_bytes)
                # allow equality — greedy is not globally optimal, but a
                # strict improvement for EVERY other PU means imbalance
                if new_max < w[heaviest] - 1e-9:
                    # at least this is not catastrophic: heaviest - lightest
                    # bounded by largest node weight
                    big = max(g.nodes[m].weight_bytes for m in a.nodes_on(heaviest))
                    assert w[heaviest] - min(w[p] for p in imc_ids) <= big + 1e-9
                    return


class TestRR:
    def test_cyclic_assignment_on_chain(self):
        g = Graph()
        prev = None
        for i in range(6):
            n = g.add(f"c{i}", OpKind.CONV, flops=1e6, weight_bytes=1e3,
                      out_bytes=1e3, out_elems=1e3,
                      meta=dict(cin_kk=64, cout=64, n_vectors=64))
            if prev is not None:
                g.add_edge(prev, n.node_id)
            prev = n.node_id
        cm = CostModel(ROOMY)
        a = get_scheduler("rr", cm).schedule(g, make_pus(3, 1))
        # chain of 6 IMC nodes over 3 IMC PUs -> 1,2,3,1,2,3
        assert [a.mapping[i] for i in range(1, 7)] == [1, 2, 3, 1, 2, 3]


class TestRD:
    @given(seed=st.integers(0, 100))
    @settings(max_examples=20, deadline=None)
    def test_seeding_phase_covers_pus(self, seed):
        g = build_random_graph(16, 0.3, seed, imc_fraction=0.7)
        cm = CostModel(ROOMY)
        fleet = make_pus(3, 2)
        a = get_scheduler("rd", cm, seed=seed).schedule(g, fleet)
        n_imc = g.num_nodes(pu_type=PUType.IMC)
        n_dpu = g.num_nodes(pu_type=PUType.DPU)
        used = {a.mapping[n] for n in a.mapping}
        # every PU that could receive a node got at least one
        if n_imc >= 3 and n_dpu >= 2:
            assert used == {1, 2, 3, 4, 5}


class TestOptimal:
    def test_rejects_large_graphs(self):
        g = build_random_graph(40, 0.2, 1)
        with pytest.raises(ValueError):
            OptimalScheduler(CostModel(ROOMY)).schedule(g, make_pus(2, 1))

    @given(seed=st.integers(0, 200))
    @settings(max_examples=25, deadline=None)
    def test_never_worse_than_any_heuristic(self, seed):
        g = build_random_graph(12, 0.3, seed)
        cm = CostModel(ROOMY)
        fleet = make_pus(3, 2)
        b_opt = OptimalScheduler(cm).schedule(g, fleet).bottleneck(g, cm)
        for alg in ALL_ALGS:
            b = get_scheduler(alg, cm).schedule(g, fleet).bottleneck(g, cm)
            assert b_opt <= b * (1 + 1e-9), alg


class TestLBLPX:
    @given(seed=st.integers(0, 120))
    @settings(max_examples=20, deadline=None)
    def test_never_worse_bottleneck_than_lblp(self, seed):
        g = build_random_graph(14, 0.3, seed)
        cm = CostModel(ROOMY)
        fleet = make_pus(3, 2)
        b_lblp = get_scheduler("lblp", cm).schedule(g, fleet).bottleneck(g, cm)
        b_x = get_scheduler("lblp-x", cm).schedule(g, fleet).bottleneck(g, cm)
        assert b_x <= b_lblp * (1 + 1e-9)
