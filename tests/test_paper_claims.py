"""Validation of the paper's §V experimental claims on our IMCE
simulator + calibrated cost model (EXPERIMENTS.md §Paper-validation).

Absolute milliseconds are not reproducible (the paper's per-node FPGA
measurements are unpublished); every *relative* claim is validated here.
"""

import pytest

from repro.core import (CostModel, IMCESimulator, get_scheduler, make_pus)
from repro.models.cnn.graphs import (resnet8_graph, resnet18_graph,
                                     yolov8n_graph)

ALGS = ("lblp", "wb", "rr", "rd")


def run_all(g, n_imc, n_dpu, frames=96):
    cm = CostModel()
    sim = IMCESimulator(g, cm)
    out = {}
    for alg in ALGS:
        a = get_scheduler(alg, cm).schedule(g, make_pus(n_imc, n_dpu))
        out[alg] = sim.run(a, frames=frames)
    return out


@pytest.fixture(scope="module")
def resnet18_12pu():
    return run_all(resnet18_graph(), 8, 4, frames=128)


class TestFig2ResNet8:
    """Fig. 2: LBLP best rate & latency at every PU count; convergence
    when #PUs == #nodes (14)."""

    @pytest.mark.parametrize("n_imc,n_dpu", [(2, 1), (4, 2), (7, 3), (10, 4)])
    def test_lblp_best_rate_and_latency(self, n_imc, n_dpu):
        res = run_all(resnet8_graph(), n_imc, n_dpu)
        best_rate = max(r.rate for r in res.values())
        best_lat = min(r.latency for r in res.values())
        assert res["lblp"].rate >= best_rate * 0.999
        assert res["lblp"].latency <= best_lat * 1.001

    def test_convergence_at_14_pus(self):
        res = run_all(resnet8_graph(), 10, 4)
        rates = [r.rate for r in res.values()]
        lats = [r.latency for r in res.values()]
        assert max(rates) / min(rates) < 1.001
        assert max(lats) / min(lats) < 1.001


class TestFig3TableIResNet18:
    """Fig. 3 + Table I: 12 PUs (8 IMC + 4 DPU)."""

    def test_lblp_dominates(self, resnet18_12pu):
        res = resnet18_12pu
        assert res["lblp"].rate >= max(r.rate for r in res.values()) * 0.999
        assert res["lblp"].latency <= min(r.latency for r in res.values()) * 1.001

    def test_rate_gain_over_wb(self, resnet18_12pu):
        """Paper: 'LBLP achieves more than 2x processing rate'."""
        ratio = resnet18_12pu["lblp"].rate / resnet18_12pu["wb"].rate
        assert ratio >= 2.0

    def test_latency_gain_over_wb(self, resnet18_12pu):
        """Paper: 'x1.4 less latency compared to WB'."""
        ratio = resnet18_12pu["wb"].latency / resnet18_12pu["lblp"].latency
        assert 1.2 <= ratio <= 1.9

    def test_utilization_contrast(self, resnet18_12pu):
        """Paper Table I: 78.3% mean utilization for LBLP vs 24.4% for WB
        (their mean over all PUs; our IMC-PU mean ~79% and WB collapses
        to ~12-25%)."""
        lblp, wb = resnet18_12pu["lblp"], resnet18_12pu["wb"]
        imc_ids = range(1, 9)
        lblp_imc = sum(lblp.utilization[p] for p in imc_ids) / 8
        wb_imc = sum(wb.utilization[p] for p in imc_ids) / 8
        assert lblp_imc >= 0.70           # paper: 78.3%
        assert wb_imc <= 0.35             # paper: 24.4%
        assert lblp_imc > 2.5 * wb_imc

    def test_wb_weight_balance_vs_time_imbalance(self, resnet18_12pu):
        """WB's defining property: weights nearly equal across IMC PUs
        while execution-time loads collapse."""
        cm = CostModel()
        g = resnet18_graph()
        a = get_scheduler("wb", cm).schedule(g, make_pus(8, 4))
        w = a.weights(g)
        imc_w = [w[p] for p in range(1, 9)]
        # paper Table I WB row spans 28.1%..100% (ratio 3.56): the three
        # indivisible 590KB stage-4 convs bound how balanced WB can get
        assert max(imc_w) / max(min(imc_w), 1.0) < 4.0   # weights balanced
        load = a.load(g, cm)
        imc_l = [load[p] for p in range(1, 9)]
        assert max(imc_l) / max(min(imc_l), 1e-12) > 5.0  # time collapsed


class TestFig4IMCvsDPUSplit:
    """Fig. 4: at fixed 12 PUs, LBLP > WB for every IMC/DPU split."""

    @pytest.mark.parametrize("n_dpu", [2, 4, 6])
    def test_lblp_beats_wb_all_splits(self, n_dpu):
        res = run_all(resnet18_graph(), 12 - n_dpu, n_dpu)
        assert res["lblp"].rate > res["wb"].rate
        assert res["lblp"].latency <= res["wb"].latency * 1.001


class TestYOLOv8n:
    """§V.C: YOLO is mostly sequential; parallelism affects <= ~10% of
    latency, measured LBLP-vs-WB isolated-latency gap small (paper: up
    to 6% under their measurement protocol)."""

    def test_off_path_share_near_10pct(self):
        g = yolov8n_graph()
        cm = CostModel()
        crit = g.critical_time(lambda n: cm.time(n))
        total = sum(cm.time(n) for n in g.nodes.values() if not n.is_free())
        assert 0.05 <= (total - crit) / total <= 0.20   # paper: ~10%

    def test_isolated_latency_gap_bounded(self):
        g = yolov8n_graph()
        cm = CostModel()
        sim = IMCESimulator(g, cm)
        gaps = []
        for n_imc, n_dpu in ((12, 6), (16, 8)):
            lat = {}
            for alg in ("lblp", "wb"):
                a = get_scheduler(alg, cm).schedule(g, make_pus(n_imc, n_dpu))
                lat[alg] = sim.latency_only(a)
            gaps.append(abs(lat["wb"] - lat["lblp"]) / min(lat.values()))
        assert max(gaps) <= 0.10    # bounded by the parallelizable share

    def test_lblp_rate_still_wins(self):
        res = run_all(yolov8n_graph(), 16, 8, frames=48)
        assert res["lblp"].rate >= res["wb"].rate
