"""Beyond-paper claims as assertions: sensitivity robustness, LM-tier
pipeline partitioning, and the whisper enc-dec serve path."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_archs, get_config
from repro.core import CostModel, IMCESimulator, get_scheduler, make_pus
from repro.core.cost import IMCE_DEFAULT
from repro.core.pipeline_partition import partition
from repro.models.cnn.graphs import resnet18_graph
from repro.models.lm import model, transformer


class TestSensitivity:
    """The paper's rate ordering is calibration-robust (benchmarks/
    sensitivity.py sweeps wider; this asserts the endpoints)."""

    @pytest.mark.parametrize("param,value", [
        ("t_mvm", 50e-9), ("t_mvm", 1000e-9),
        ("dpu_elem_rate", 0.5e9), ("dpu_elem_rate", 8.0e9),
        ("dram_bw", 2e9), ("xbars_per_pu", 1),
    ])
    def test_lblp_rate_dominates_across_calibrations(self, param, value):
        prof = replace(IMCE_DEFAULT, name="sweep", **{param: value})
        cm = CostModel(prof)
        g = resnet18_graph()
        fleet = make_pus(8, 4, prof)
        sim = IMCESimulator(g, cm)
        res = {alg: sim.run(get_scheduler(alg, cm).schedule(g, fleet),
                            frames=64)
               for alg in ("lblp", "wb", "rr", "rd")}
        assert res["lblp"].rate >= max(r.rate for r in res.values()) * 0.999
        assert res["lblp"].rate / res["wb"].rate > 2.0


class TestLMPartition:
    """LBLP stage balancing beats uniform chunking on heterogeneous
    stacks and never loses on homogeneous ones."""

    @pytest.mark.parametrize("arch", ["whisper-small", "gemma3-1b",
                                      "recurrentgemma-9b",
                                      "qwen3-moe-235b-a22b"])
    def test_beats_uniform_on_heterogeneous(self, arch):
        from benchmarks.lm_partition import uniform_imbalance
        cfg = get_config(arch)
        u = uniform_imbalance(cfg, 8)
        plan = partition(cfg, 8)
        assert plan.imbalance <= u + 1e-9
        assert plan.imbalance < 2.0

    @pytest.mark.parametrize("arch", all_archs())
    def test_partition_covers_all_blocks(self, arch):
        plan = partition(get_config(arch), 4)
        stages = set(plan.stage_of.values())
        assert stages == set(range(4))


class TestWhisperServe:
    def test_encdec_prefill_decode(self):
        cfg = get_config("whisper-small").smoke()
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        B, S_enc = 2, 32
        batch = {
            "enc_frames": jax.random.normal(
                jax.random.PRNGKey(1), (B, S_enc, cfg.enc_frame_dim),
                jnp.bfloat16),
            "tokens": jax.random.randint(
                jax.random.PRNGKey(2), (B, 6), 0, cfg.vocab, jnp.int32),
        }
        logits, cache = model.make_prefill_step(cfg, s_max=32)(params, batch)
        assert jnp.isfinite(logits).all()
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        dec = model.make_decode_step(cfg)
        for _ in range(3):
            logits, cache = dec(params, tok, cache)
            assert jnp.isfinite(logits).all()
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)

    def test_cross_attention_sees_encoder(self):
        """Changing the audio changes the decoder logits."""
        cfg = get_config("whisper-small").smoke()
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.zeros((1, 4), jnp.int32)
        f1 = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.enc_frame_dim),
                               jnp.bfloat16)
        f2 = jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg.enc_frame_dim),
                               jnp.bfloat16)
        h1 = transformer.forward_train(cfg, params, toks, enc_frames=f1)
        h2 = transformer.forward_train(cfg, params, toks, enc_frames=f2)
        assert not jnp.allclose(h1, h2)
