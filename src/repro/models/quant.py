"""INT8 post-training quantization (the paper deploys INT8 models).

Scheme (matches common IMC deployments and our Pallas ``imc_mvm`` kernel):

* **Weights** — symmetric per-output-channel INT8:
  ``q_w[..., c] = round(w[..., c] / s_w[c])``, ``s_w[c] = max|w[...,c]| / 127``.
* **Activations** — symmetric per-tensor INT8 with calibration:
  ``s_x = max|x| / 127`` over a calibration batch.
* **Compute** — INT8 x INT8 -> INT32 accumulate (exact), then dequantize
  ``y = acc * s_x * s_w + b`` (bias kept float, folded from BN).
* **Optional AIMC noise hook** — additive Gaussian on the accumulator,
  emulating analog crossbar noise (the IMCE's "optional noise modeling").

All functions are pure-jnp and jit-safe; the Pallas kernel in
``repro.kernels.imc_mvm`` implements the same integer semantics on TPU
and is tested against ``quantized_matmul`` bit-exactly.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jnp.ndarray          # int8 values
    scale: jnp.ndarray      # per-channel (weights) or scalar (activations)


def weight_scale(w: jnp.ndarray, channel_axis: int = -1) -> jnp.ndarray:
    axes = tuple(i for i in range(w.ndim) if i != channel_axis % w.ndim)
    amax = jnp.max(jnp.abs(w), axis=axes)
    return jnp.maximum(amax, 1e-8) / 127.0


def quantize_weight(w: jnp.ndarray, channel_axis: int = -1) -> QTensor:
    s = weight_scale(w, channel_axis)
    shape = [1] * w.ndim
    shape[channel_axis % w.ndim] = -1
    q = jnp.clip(jnp.round(w / s.reshape(shape)), -127, 127).astype(jnp.int8)
    return QTensor(q, s)


def act_scale(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0


def quantize_act(x: jnp.ndarray, scale: Optional[jnp.ndarray] = None) -> QTensor:
    s = act_scale(x) if scale is None else scale
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return QTensor(q, s)


def dequantize(t: QTensor, channel_axis: int = -1) -> jnp.ndarray:
    s = t.scale
    if s.ndim > 0 and s.size > 1:
        shape = [1] * t.q.ndim
        shape[channel_axis % t.q.ndim] = -1
        s = s.reshape(shape)
    return t.q.astype(jnp.float32) * s


# ---------------------------------------------------------------------------
# integer compute paths (bit-exact oracles for the Pallas kernels)
# ---------------------------------------------------------------------------

def int8_matmul_acc(qx: jnp.ndarray, qw: jnp.ndarray) -> jnp.ndarray:
    """INT8 x INT8 -> INT32 exact accumulation."""
    return jax.lax.dot_general(
        qx.astype(jnp.int32), qw.astype(jnp.int32),
        (((qx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def quantized_matmul(x: jnp.ndarray, w: jnp.ndarray,
                     b: Optional[jnp.ndarray] = None,
                     x_scale: Optional[jnp.ndarray] = None,
                     noise_std: float = 0.0,
                     key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Quantize -> integer matmul -> dequantize (+ optional AIMC noise)."""
    qx = quantize_act(x, x_scale)
    qw = quantize_weight(w, channel_axis=-1)
    acc = int8_matmul_acc(qx.q, qw.q).astype(jnp.float32)
    if noise_std > 0.0 and key is not None:
        acc = acc + noise_std * jax.random.normal(key, acc.shape)
    y = acc * qx.scale * qw.scale
    if b is not None:
        y = y + b
    return y


def quantized_conv2d(x: jnp.ndarray, w: jnp.ndarray,
                     b: Optional[jnp.ndarray] = None,
                     stride: int = 1, padding: str = "SAME",
                     x_scale: Optional[jnp.ndarray] = None,
                     noise_std: float = 0.0,
                     key: Optional[jax.Array] = None) -> jnp.ndarray:
    """INT8 conv via integer accumulate, NHWC/HWIO."""
    qx = quantize_act(x, x_scale)
    qw = quantize_weight(w, channel_axis=-1)
    acc = jax.lax.conv_general_dilated(
        qx.q.astype(jnp.int32), qw.q.astype(jnp.int32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    if noise_std > 0.0 and key is not None:
        acc = acc + noise_std * jax.random.normal(key, acc.shape)
    y = acc * qx.scale * qw.scale
    if b is not None:
        y = y + b
    return y


# ---------------------------------------------------------------------------
# whole-model PTQ calibration
# ---------------------------------------------------------------------------

def calibrate_resnet(params: Dict, x: jnp.ndarray, cfg: dict) -> Dict[str, float]:
    """Record per-layer input activation scales on a calibration batch by
    replaying the reference forward pass."""
    scales: Dict[str, float] = {}

    # trace manually, mirroring resnet.forward
    from .cnn import layers as L

    def rec(name, t):
        scales[name] = float(act_scale(t))

    rec("stem", x)
    h = L.conv2d(params["stem"], x, stride=1, act="relu")
    for si, blocks in enumerate(params["stages"]):
        for bi, block in enumerate(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            identity = h
            rec(f"s{si}b{bi}.conv1", h)
            y = L.conv2d(block["conv1"], h, stride=stride, act="relu")
            rec(f"s{si}b{bi}.conv2", y)
            y = L.conv2d(block["conv2"], y, stride=1, act=None)
            if "down" in block:
                rec(f"s{si}b{bi}.down", identity)
                identity = L.conv2d(block["down"], identity, stride=stride,
                                    act=None)
            h = jax.nn.relu(y + identity)
    g = jnp.mean(h, axis=(1, 2))
    rec("fc", g)
    return scales
