"""Grouped-query attention with the variants the assigned archs need:

* GQA / MQA / MHA (``n_kv_heads`` divides ``n_heads``)
* causal masking; sliding-window (local) masking with a *dynamic* window
  so one scan body serves mixed local/global stacks (gemma2/gemma3)
* attention-logit softcapping (gemma2)
* cross-attention (whisper decoder)
* prefill (full sequence) and single-token decode against a KV cache

Shapes: hidden (B, S, D); q/k/v (B, S, H, hd); caches (B, S_max, KV, hd).
Pure jnp — XLA fuses this well and it lowers/shards everywhere; the
Pallas flash kernel (repro.kernels.flash_attention) is an optional
drop-in for the TPU hot path (kernels are validated in interpret mode).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import rope
from .sharding import constrain


def _pad_heads(q, k, v):
    """Zero-pad the head axis to the next multiple of the model-axis size
    so attention shards by head (perf iteration #8, EXPERIMENTS §Perf).

    Non-divisible head counts (24/12/8/4 on a 16-wide model axis) would
    otherwise force either full replication of the quadratic attention or
    sequence-parallelism with per-layer k/v all-gathers (measured 11x the
    compute term on starcoder2).  Padded q heads see zero k/v and their
    output is sliced off before wo — numerics are untouched; the cost is
    (H_pad/H - 1) extra attention FLOPs, strictly cheaper than either
    alternative at these geometries.  Returns (q, k, v, real_H).
    """
    from .sharding import _ACT_MESH
    mesh = _ACT_MESH.get()
    H = q.shape[2]
    if mesh is None:
        return q, k, v, H
    m = mesh.shape["model"]
    if H % m == 0:
        return q, k, v, H
    pad = (-H) % m
    zq = [(0, 0)] * q.ndim
    zq[2] = (0, pad)
    return (jnp.pad(q, zq), jnp.pad(k, zq), jnp.pad(v, zq), H)


def _constrain_attn(q, k, v):
    """Pin attention activation sharding: batch over data, heads over
    'model' (head counts are pre-padded to divide the axis)."""
    def spec(mesh, dp):
        if q.shape[2] % mesh.shape["model"] == 0:
            return [dp, None, "model", None]
        return [dp, None, None, None]

    return constrain(q, spec), constrain(k, spec), constrain(v, spec)


class AttnParams(NamedTuple):
    wq: jnp.ndarray      # (D, H, hd)
    wk: jnp.ndarray      # (D, KV, hd)
    wv: jnp.ndarray      # (D, KV, hd)
    wo: jnp.ndarray      # (H, hd, D)


def init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
         dtype=jnp.bfloat16) -> AttnParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    so = 1.0 / math.sqrt(n_heads * head_dim)
    return AttnParams(
        wq=(jax.random.normal(k1, (d_model, n_heads, head_dim)) * s).astype(dtype),
        wk=(jax.random.normal(k2, (d_model, n_kv, head_dim)) * s).astype(dtype),
        wv=(jax.random.normal(k3, (d_model, n_kv, head_dim)) * s).astype(dtype),
        wo=(jax.random.normal(k4, (n_heads, head_dim, d_model)) * so).astype(dtype),
    )


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating groups."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Additive attention bias (Sq, Sk) from causal + sliding-window rules.

    ``window`` may be a traced scalar (dynamic per-layer window; a huge
    value means global attention) or None.
    """
    d = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(d.shape, dtype=bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           bias: Optional[jnp.ndarray], softcap: Optional[float],
           scale: float) -> jnp.ndarray:
    """Core softmax attention; q (B,Sq,H,hd), k/v (B,Sk,H,hd)."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    if bias is not None:
        logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


#: sequences at least this long use q-chunked attention (bounded memory)
CHUNK_THRESHOLD = 8192
Q_CHUNK = 1024


def forward(p: AttnParams, x: jnp.ndarray, positions: jnp.ndarray,
            *, causal: bool = True, window: Optional[jnp.ndarray] = None,
            softcap: Optional[float] = None, use_rope: bool = True,
            kv_from: Optional[jnp.ndarray] = None,
            chunk_scan: bool = True) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).

    ``kv_from``: cross-attention source (B, S_enc, D); disables rope/causal
    on the keys when provided.

    For long sequences (>= CHUNK_THRESHOLD) the query axis is processed in
    chunks (a statically-unrolled python loop, so dry-run cost analysis
    stays exact): attention logits never materialize beyond
    (B, H, Q_CHUNK, S).  This is the jnp analogue of the Pallas flash
    kernel's outer loop and keeps 32k-prefill within HBM.
    """
    B, S, D = x.shape
    H, hd = p.wq.shape[1], p.wq.shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    src = x if kv_from is None else kv_from
    k = jnp.einsum("bsd,dhk->bshk", src, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", src, p.wv)
    if use_rope and kv_from is None:
        cos, sin = rope.rope_angles(positions, hd)
        q = rope.apply_rope(q, cos, sin)
        k = rope.apply_rope(k, cos, sin)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    q, k, v, real_h = _pad_heads(q, k, v)
    q, k, v = _constrain_attn(q, k, v)
    scale = 1.0 / math.sqrt(hd)

    if kv_from is not None:
        out = attend(q, k, v, None, softcap, scale)[:, :, :real_h]
        return jnp.einsum("bqhd,hdk->bqk", out, p.wo)

    if S < CHUNK_THRESHOLD:
        bias = _mask_bias(positions, positions, causal, window)[None, None]
        out = attend(q, k, v, bias, softcap, scale)[:, :, :real_h]
        return jnp.einsum("bqhd,hdk->bqk", out, p.wo)

    # q-chunked path (bounded logits memory)
    if chunk_scan and S % Q_CHUNK == 0:
        # sequential chunks via lax.scan: one chunk's logits live at a time
        n_c = S // Q_CHUNK
        qs = q.reshape(q.shape[0], n_c, Q_CHUNK, *q.shape[2:])
        qs = jnp.moveaxis(qs, 1, 0)               # (n_c, B, c, H, hd)

        def chunk(_, inp):
            i, qc = inp
            qpos = i * Q_CHUNK + jnp.arange(Q_CHUNK)
            bias = _mask_bias(qpos, positions, causal, window)[None, None]
            return None, attend(qc, k, v, bias, softcap, scale)

        _, outs = jax.lax.scan(chunk, None,
                               (jnp.arange(n_c), qs))
        out = jnp.moveaxis(outs, 0, 1).reshape(q.shape[0], S, *q.shape[2:])
        out = out[:, :, :real_h]
        return jnp.einsum("bqhd,hdk->bqk", out, p.wo)
    outs = []
    for i0 in range(0, S, Q_CHUNK):
        qc = q[:, i0: i0 + Q_CHUNK]
        bias = _mask_bias(positions[i0: i0 + Q_CHUNK], positions, causal,
                          window)[None, None]
        outs.append(attend(qc, k, v, bias, softcap, scale)[:, :, :real_h])
    out = jnp.concatenate(outs, axis=1)
    return jnp.einsum("bqhd,hdk->bqk", out, p.wo)


# ---------------------------------------------------------------------------
# KV-cache decode path
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray       # (B, S_max, KV, hd)
    v: jnp.ndarray       # (B, S_max, KV, hd)


def init_cache(batch: int, s_max: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, s_max, n_kv, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def prefill(p: AttnParams, x: jnp.ndarray, positions: jnp.ndarray,
            s_max: int, *, use_rope: bool = True) -> KVCache:
    """Compute and store K/V for the prompt (padded to s_max)."""
    hd = p.wk.shape[2]
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv)
    if use_rope:
        cos, sin = rope.rope_angles(positions, hd)
        k = rope.apply_rope(k, cos, sin)
    pad = s_max - k.shape[1]
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return KVCache(k, v)


def cross_decode(p: AttnParams, x: jnp.ndarray, cache: KVCache) -> jnp.ndarray:
    """Cross-attention during decode: static (unpadded) encoder KV cache."""
    H, hd = p.wq.shape[1], p.wq.shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k = _expand_kv(cache.k, H)
    v = _expand_kv(cache.v, H)
    out = attend(q, k, v, None, None, 1.0 / math.sqrt(hd))
    return jnp.einsum("bqhd,hdk->bqk", out, p.wo)


def decode_step(p: AttnParams, x: jnp.ndarray, cache: KVCache,
                cur_pos: jnp.ndarray, *, window: Optional[jnp.ndarray] = None,
                softcap: Optional[float] = None, use_rope: bool = True,
                ) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode: x (B, 1, D); cur_pos scalar int (tokens so far).

    Updates the cache in place (functionally) at ``cur_pos`` and attends
    over positions [0, cur_pos] (optionally windowed).
    """
    B, _, D = x.shape
    H, hd = p.wq.shape[1], p.wq.shape[2]
    S_max = cache.k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k_new = jnp.einsum("bsd,dhk->bshk", x, p.wk)
    v_new = jnp.einsum("bsd,dhk->bshk", x, p.wv)
    if use_rope:
        cos, sin = rope.rope_angles(cur_pos[None], hd)
        q = rope.apply_rope(q, cos, sin)
        k_new = rope.apply_rope(k_new, cos, sin)
    k_cache = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, cur_pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, cur_pos, 0, 0))
    k = _expand_kv(k_cache, H)
    v = _expand_kv(v_cache, H)
    k_pos = jnp.arange(S_max)
    valid = k_pos <= cur_pos
    if window is not None:
        valid &= (cur_pos - k_pos) < window
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, None, None, :]
    out = attend(q, k, v, bias, softcap, 1.0 / math.sqrt(hd))
    y = jnp.einsum("bqhd,hdk->bqk", out, p.wo)
    return y, KVCache(k_cache, v_cache)
