"""Rotary position embeddings (RoPE), decode-aware."""

from __future__ import annotations

import jax.numpy as jnp


def rope_angles(positions: jnp.ndarray, head_dim: int,
                theta: float = 10_000.0) -> tuple:
    """(…,) int positions -> (…, head_dim/2) cos/sin tables."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (seq, head_dim/2).

    Rotates pairs (x[2i], x[2i+1]) — the interleaved convention.
    """
    half = x.shape[-1] // 2
    x1 = x[..., :half]
    x2 = x[..., half:]
    # broadcast cos/sin over head axis
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out1 = x1 * c - x2 * s
    out2 = x2 * c + x1 * s
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
