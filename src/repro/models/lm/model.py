"""Model API: loss, train_step (with gradient accumulation), serve steps,
and per-(arch x shape) input specs for the dry-run.

Everything here is built to be ``jax.jit``-ed with explicit shardings by
the launcher; no jit happens at import.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, ShapeSpec
from repro.optim import adamw

from . import transformer


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------

def make_batch_spec(cfg: LMConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.bfloat16, jnp.int32
    if shape.mode == "train":
        if cfg.is_encdec():
            dec = max(S // cfg.dec_len_ratio, 8)
            return {
                "enc_frames": jax.ShapeDtypeStruct((B, S, cfg.enc_frame_dim), f32),
                "tokens": jax.ShapeDtypeStruct((B, dec), i32),
                "labels": jax.ShapeDtypeStruct((B, dec), i32),
            }
        if cfg.num_prefix_tokens:
            text = S - cfg.num_prefix_tokens
            return {
                "prefix": jax.ShapeDtypeStruct(
                    (B, cfg.num_prefix_tokens, cfg.prefix_dim), f32),
                "tokens": jax.ShapeDtypeStruct((B, text), i32),
                "labels": jax.ShapeDtypeStruct((B, text), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if shape.mode == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encdec():
            dec = max(S // cfg.dec_len_ratio, 8)
            spec = {
                "enc_frames": jax.ShapeDtypeStruct((B, S, cfg.enc_frame_dim), f32),
                "tokens": jax.ShapeDtypeStruct((B, dec), i32),
            }
        elif cfg.num_prefix_tokens:
            spec = {
                "prefix": jax.ShapeDtypeStruct(
                    (B, cfg.num_prefix_tokens, cfg.prefix_dim), f32),
                "tokens": jax.ShapeDtypeStruct(
                    (B, S - cfg.num_prefix_tokens), i32),
            }
        return spec
    # decode: one new token against an S-long cache
    return {"token": jax.ShapeDtypeStruct((B, 1), i32)}


def synth_batch(cfg: LMConfig, shape: ShapeSpec, key) -> Dict[str, jnp.ndarray]:
    """Concrete random batch matching make_batch_spec (smoke tests)."""
    spec = make_batch_spec(cfg, shape)
    out = {}
    for name, sds in spec.items():
        key, sub = jax.random.split(key)
        if sds.dtype == jnp.int32:
            out[name] = jax.random.randint(sub, sds.shape, 0, cfg.vocab,
                                           dtype=jnp.int32)
        else:
            out[name] = jax.random.normal(sub, sds.shape, dtype=sds.dtype)
    return out


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(cfg: LMConfig, params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Mean next-token cross-entropy (f32)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    hidden = transformer.forward_train(
        cfg, params, tokens,
        enc_frames=batch.get("enc_frames"),
        prefix=batch.get("prefix"),
    )
    if batch.get("prefix") is not None:
        hidden = hidden[:, batch["prefix"].shape[1]:, :]
    logits = transformer.logits_head(cfg, params, hidden)
    # shift: predict t+1 from t
    logits = logits[:, :-1]
    targets = labels[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# train step (microbatched gradient accumulation)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainStepConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()


def _mesh_axis_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def make_train_step(cfg: LMConfig, tcfg: Optional[TrainStepConfig] = None,
                    microbatch: Optional[int] = None, mesh=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    The global batch is split into microbatches scanned sequentially with
    f32 gradient accumulation (constant memory in global batch size).
    Microbatches are taken with shard-aligned ``dynamic_slice`` over the
    batch dim + explicit sharding constraints (``mesh``), so GSPMD keeps
    every microbatch data-sharded and the remat stash is bounded by the
    microbatch, not the global batch.
    """
    tcfg = tcfg or TrainStepConfig()

    def constrain_batch(b):
        if mesh is None:
            return b
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = ("pod", "data") if "pod" in mesh.shape else ("data",)

        def rule(x):
            spec = [dp] + [None] * (x.ndim - 1)
            if x.shape[0] % _mesh_axis_size(mesh, dp) != 0:
                spec[0] = None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))
        return jax.tree_util.tree_map(rule, b)

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        mb = min(microbatch or cfg.microbatch, B)
        n_mb = max(B // mb, 1)

        grad_fn = jax.value_and_grad(lambda p, b: loss_fn(cfg, p, b))

        if n_mb == 1:
            # no accumulation loop (also keeps dry-run cost analysis exact)
            loss, grads = grad_fn(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        else:
            def accum(carry, i):
                gsum, lsum = carry
                mb_batch = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb,
                                                           axis=0),
                    batch)
                mb_batch = constrain_batch(mb_batch)
                loss, grads = grad_fn(params, mb_batch)
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0),
                                           jnp.arange(n_mb))
            grads = jax.tree_util.tree_map(lambda g: g / n_mb, gsum)
            loss = lsum / n_mb
        new_params, new_opt, metrics = adamw.apply(
            tcfg.opt, params, opt_state, grads)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: LMConfig, s_max: int):
    def prefill_step(params, batch):
        return transformer.prefill(
            cfg, params, batch["tokens"], s_max,
            enc_frames=batch.get("enc_frames"),
            prefix=batch.get("prefix"))
    return prefill_step


def make_decode_step(cfg: LMConfig):
    def decode_step(params, token, cache):
        return transformer.decode(cfg, params, token, cache)
    return decode_step


def init_cache_spec(cfg: LMConfig, shape: ShapeSpec
                    ) -> transformer.ServeCache:
    """Abstract ServeCache (ShapeDtypeStructs) for decode-mode dry-runs:
    the cache a prefill of length seq_len would have produced."""
    B, S = shape.global_batch, shape.seq_len

    def attn_entry(n):
        kv = jax.ShapeDtypeStruct((n, B, S, cfg.n_kv_heads, cfg.hd),
                                  jnp.bfloat16)
        return transformer.attention.KVCache(kv, kv)

    def ssm_entry(n):
        return transformer.ssm.SSMState(
            conv=jax.ShapeDtypeStruct((n, B, cfg.conv_k - 1, cfg.d_inner),
                                      jnp.bfloat16),
            h=jax.ShapeDtypeStruct((n, B, cfg.d_inner, cfg.ssm_state),
                                   jnp.float32))

    def rec_entry(n):
        return transformer.rglru.RGLRUState(
            conv=jax.ShapeDtypeStruct((n, B, cfg.conv_k - 1, cfg.d_inner),
                                      jnp.bfloat16),
            h=jax.ShapeDtypeStruct((n, B, cfg.d_inner), jnp.float32))

    entries = []
    for seg in cfg.segments:
        if seg.kind == "attn":
            entries.append(attn_entry(seg.n))
        elif seg.kind == "ssm":
            entries.append(ssm_entry(seg.n))
        elif seg.kind == "rec":
            entries.append(rec_entry(seg.n))
        elif seg.kind == "hybrid3":
            entries.append((rec_entry(seg.n), rec_entry(seg.n),
                            attn_entry(seg.n)))
        elif seg.kind == "xattn":
            self_kv = attn_entry(seg.n)
            cross = transformer.attention.KVCache(
                jax.ShapeDtypeStruct((seg.n, B, S, cfg.n_kv_heads, cfg.hd),
                                     jnp.bfloat16),
                jax.ShapeDtypeStruct((seg.n, B, S, cfg.n_kv_heads, cfg.hd),
                                     jnp.bfloat16))
            entries.append((self_kv, cross))
        else:
            raise ValueError(seg.kind)
    return transformer.ServeCache(tuple(entries),
                                  jax.ShapeDtypeStruct((), jnp.int32))


def abstract_params(cfg: LMConfig):
    """Parameter ShapeDtypeStructs without allocation (dry-run)."""
    return jax.eval_shape(partial(transformer.init_params, cfg),
                          jax.random.PRNGKey(0))


def abstract_opt_state(cfg: LMConfig):
    """Optimizer-state ShapeDtypeStructs without allocation (dry-run)."""
    return jax.eval_shape(
        lambda key: adamw.init(transformer.init_params(cfg, key)),
        jax.random.PRNGKey(0))
