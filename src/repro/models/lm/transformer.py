"""Scan-based transformer/SSM/hybrid stacks for the assigned archs.

One ``lax.scan`` per config *segment* over stacked layer parameters
(compile O(1) in depth); per-layer sliding windows ride along as scan xs
so mixed local/global stacks share one body.  Three execution modes:

* ``forward_train`` — full-sequence, remat'd scan bodies, returns hidden
  states for the loss head.
* ``prefill``       — full-sequence, emits per-layer caches (KV / SSM /
  recurrent states) stacked (L, ...) as scan ys, plus last-position
  hidden state.
* ``decode``        — one token against stacked caches (donated).

Whisper (enc-dec) runs a non-causal encoder stack and a decoder stack
with cross-attention; the conv/mel frontend is stubbed (precomputed frame
embeddings are the model input, per the assignment).  PaliGemma prepends
stub image-patch embeddings to the token embeddings.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, Segment

from . import attention, mlp, moe, rglru, ssm
from .sharding import constrain_tokens


# ---------------------------------------------------------------------------
# norms (config-selected)
# ---------------------------------------------------------------------------

def norm_init(cfg: LMConfig):
    if cfg.norm_kind == "ln":
        return mlp.layernorm_init(cfg.d_model)
    return mlp.rmsnorm_init(cfg.d_model)


def norm_apply(cfg: LMConfig, p, x):
    if cfg.norm_kind == "ln":
        return mlp.layernorm(p, x)
    return mlp.rmsnorm(p, x)


# ---------------------------------------------------------------------------
# per-layer block params
# ---------------------------------------------------------------------------

def _ffn_init(cfg: LMConfig, key):
    if cfg.n_experts:
        return moe.init(key, cfg.d_model, cfg.d_ff, cfg.n_experts)
    if cfg.mlp_kind == "plain":
        return mlp.init_plain(key, cfg.d_model, cfg.d_ff)
    return mlp.init_gated(key, cfg.d_model, cfg.d_ff)


def _ffn_apply(cfg: LMConfig, p, x):
    if cfg.n_experts:
        return moe.forward(p, x, cfg.top_k, cfg.capacity_factor, cfg.act)
    if cfg.mlp_kind == "plain":
        return mlp.plain(p, x, cfg.act)
    return mlp.gated(p, x, cfg.act)


def init_block(cfg: LMConfig, kind: str, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    if kind == "attn":
        return {
            "norm1": norm_init(cfg),
            "attn": attention.init(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd),
            "norm2": norm_init(cfg),
            "ffn": _ffn_init(cfg, ks[1]),
        }
    if kind == "ssm":
        return {
            "norm": norm_init(cfg),
            "ssm": ssm.init(ks[0], cfg.d_model, cfg.d_inner, cfg.ssm_state,
                            cfg.dt_rank, cfg.conv_k),
        }
    if kind == "rec":
        return {
            "norm1": norm_init(cfg),
            "rec": rglru.init(ks[0], cfg.d_model, cfg.d_inner, cfg.conv_k),
            "norm2": norm_init(cfg),
            "ffn": _ffn_init(cfg, ks[1]),
        }
    if kind == "hybrid3":
        return {
            "rec1": init_block(cfg, "rec", ks[0]),
            "rec2": init_block(cfg, "rec", ks[1]),
            "attn": init_block(cfg, "attn", ks[2]),
        }
    if kind == "xattn":
        return {
            "norm1": norm_init(cfg),
            "self": attention.init(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd),
            "norm2": norm_init(cfg),
            "cross": attention.init(ks[1], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd),
            "norm3": norm_init(cfg),
            "ffn": _ffn_init(cfg, ks[2]),
        }
    raise ValueError(f"unknown block kind {kind}")


def init_segment(cfg: LMConfig, seg: Segment, key):
    keys = jax.random.split(key, seg.n)
    return jax.vmap(lambda k: init_block(cfg, seg.kind, k))(keys)


def init_params(cfg: LMConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4 + len(cfg.segments) + len(cfg.enc_segments))
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model))
                  * (1.0 / math.sqrt(cfg.d_model))).astype(jnp.bfloat16),
        "final_norm": norm_init(cfg),
        "segments": [init_segment(cfg, seg, ks[4 + i])
                     for i, seg in enumerate(cfg.segments)],
    }
    if cfg.enc_segments:
        off = 4 + len(cfg.segments)
        params["enc_segments"] = [init_segment(cfg, seg, ks[off + i])
                                  for i, seg in enumerate(cfg.enc_segments)]
        params["enc_final_norm"] = norm_init(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab))
                             * (1.0 / math.sqrt(cfg.d_model))).astype(jnp.bfloat16)
    return params


def param_count(cfg: LMConfig) -> int:
    shapes = jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))
    return sum(math.prod(x.shape)
               for x in jax.tree_util.tree_leaves(shapes))


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------

def _sinusoid(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def embed_tokens(cfg: LMConfig, params, tokens: jnp.ndarray,
                 positions: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.pos_embed == "sinusoid":
        x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)
    return x


def logits_head(cfg: LMConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ w).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# full-sequence block application (train / prefill)
# ---------------------------------------------------------------------------

def _attn_block_fwd(cfg, p, x, positions, window, causal=True,
                    want_cache=False, s_max=0):
    x = constrain_tokens(x)
    h = norm_apply(cfg, p["norm1"], x)
    a = attention.forward(p["attn"], h, positions, causal=causal,
                          window=window, softcap=cfg.attn_softcap,
                          use_rope=(cfg.pos_embed == "rope"),
                          chunk_scan=cfg.chunk_scan)
    cache = None
    if want_cache:
        # K/V of this layer come from the same normed input the attention
        # consumed (XLA CSEs the duplicate projections).
        cache = attention.prefill(p["attn"], h, positions, s_max,
                                  use_rope=(cfg.pos_embed == "rope"))
    x = x + a
    h = norm_apply(cfg, p["norm2"], x)
    x = x + _ffn_apply(cfg, p["ffn"], h)
    return x, cache


def _ssm_block_fwd(cfg, p, x, state=None):
    x = constrain_tokens(x)
    h = norm_apply(cfg, p["norm"], x)
    y, new_state = ssm.forward(p["ssm"], h, state)
    return x + y, new_state


def _rec_block_fwd(cfg, p, x, state=None):
    x = constrain_tokens(x)
    h = norm_apply(cfg, p["norm1"], x)
    y, new_state = rglru.forward(p["rec"], h, state)
    x = x + y
    h = norm_apply(cfg, p["norm2"], x)
    x = x + _ffn_apply(cfg, p["ffn"], h)
    return x, new_state


def _xattn_block_fwd(cfg, p, x, positions, enc_out, want_cache=False,
                     s_max=0):
    x = constrain_tokens(x)
    h = norm_apply(cfg, p["norm1"], x)
    a = attention.forward(p["self"], h, positions, causal=True,
                          use_rope=(cfg.pos_embed == "rope"))
    self_cache = None
    if want_cache:
        self_cache = attention.prefill(p["self"], h, positions, s_max,
                                       use_rope=(cfg.pos_embed == "rope"))
    x = x + a
    h = norm_apply(cfg, p["norm2"], x)
    c = attention.forward(p["cross"], h, positions, kv_from=enc_out)
    cross_cache = None
    if want_cache:
        cross_cache = attention.prefill(p["cross"], enc_out,
                                        jnp.arange(enc_out.shape[1]),
                                        enc_out.shape[1], use_rope=False)
    x = x + c
    h = norm_apply(cfg, p["norm3"], x)
    x = x + _ffn_apply(cfg, p["ffn"], h)
    if want_cache:
        return x, (self_cache, cross_cache)
    return x, None


# ---------------------------------------------------------------------------
# segment scans
# ---------------------------------------------------------------------------

def _maybe_remat(cfg: LMConfig, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan(cfg: LMConfig, body, init, xs):
    """lax.scan honouring cfg.scan_unroll (full unroll gives exact
    cost_analysis FLOPs for the roofline; default rolled scan keeps
    compile O(1) in depth)."""
    unroll = getattr(cfg, "scan_unroll", False)
    return jax.lax.scan(body, init, xs, unroll=unroll or 1)


def run_segment_train(cfg: LMConfig, seg: Segment, seg_params, x,
                      positions, enc_out=None, causal=True):
    windows = jnp.array(seg.windows(), dtype=jnp.int32)

    if seg.kind == "attn":
        def body(h, inp):
            p_l, w = inp
            h, _ = _attn_block_fwd(cfg, p_l, h, positions, w, causal=causal)
            return h, None
        x, _ = _scan(cfg, _maybe_remat(cfg, body), x, (seg_params, windows))
        return x
    if seg.kind == "ssm":
        def body(h, p_l):
            h, _ = _ssm_block_fwd(cfg, p_l, h)
            return h, None
        x, _ = _scan(cfg, _maybe_remat(cfg, body), x, seg_params)
        return x
    if seg.kind == "rec":
        def body(h, p_l):
            h, _ = _rec_block_fwd(cfg, p_l, h)
            return h, None
        x, _ = _scan(cfg, _maybe_remat(cfg, body), x, seg_params)
        return x
    if seg.kind == "hybrid3":
        def body(h, inp):
            p_l, w = inp
            h, _ = _rec_block_fwd(cfg, p_l["rec1"], h)
            h, _ = _rec_block_fwd(cfg, p_l["rec2"], h)
            h, _ = _attn_block_fwd(cfg, p_l["attn"], h, positions, w)
            return h, None
        x, _ = _scan(cfg, _maybe_remat(cfg, body), x, (seg_params, windows))
        return x
    if seg.kind == "xattn":
        def body(h, p_l):
            h, _ = _xattn_block_fwd(cfg, p_l, h, positions, enc_out)
            return h, None
        x, _ = _scan(cfg, _maybe_remat(cfg, body), x, seg_params)
        return x
    raise ValueError(seg.kind)


def encode(cfg: LMConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, S_enc, D)."""
    S = frames.shape[1]
    positions = jnp.arange(S)
    x = frames.astype(jnp.bfloat16)
    if cfg.pos_embed == "sinusoid":
        x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)
    for seg, seg_params in zip(cfg.enc_segments, params["enc_segments"]):
        x = run_segment_train(cfg, seg, seg_params, x, positions,
                              causal=False)
    return norm_apply(cfg, params["enc_final_norm"], x)


def forward_train(cfg: LMConfig, params, tokens: jnp.ndarray,
                  enc_frames: Optional[jnp.ndarray] = None,
                  prefix: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Returns final hidden states (B, S_total, D)."""
    B, S = tokens.shape
    enc_out = None
    if cfg.is_encdec():
        enc_out = encode(cfg, params, enc_frames)
    if prefix is not None:
        P = prefix.shape[1]
        positions = jnp.arange(P + S)
        x_tok = embed_tokens(cfg, params, tokens, positions[P:])
        x = jnp.concatenate([prefix.astype(x_tok.dtype), x_tok], axis=1)
    else:
        positions = jnp.arange(S)
        x = embed_tokens(cfg, params, tokens, positions)
    for seg, seg_params in zip(cfg.segments, params["segments"]):
        x = run_segment_train(cfg, seg, seg_params, x, positions,
                              enc_out=enc_out)
    return norm_apply(cfg, params["final_norm"], x)


# ---------------------------------------------------------------------------
# serving: prefill + decode against stacked caches
# ---------------------------------------------------------------------------

class ServeCache(NamedTuple):
    """Per-segment stacked caches, one entry per config segment."""
    entries: Tuple[Any, ...]
    cur_pos: jnp.ndarray           # () int32 — tokens decoded so far


def prefill(cfg: LMConfig, params, tokens: jnp.ndarray, s_max: int,
            enc_frames: Optional[jnp.ndarray] = None,
            prefix: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, ServeCache]:
    """Process the prompt; returns (last-position logits, caches)."""
    B, S = tokens.shape
    enc_out = None
    if cfg.is_encdec():
        enc_out = encode(cfg, params, enc_frames)
    if prefix is not None:
        P = prefix.shape[1]
        positions = jnp.arange(P + S)
        x_tok = embed_tokens(cfg, params, tokens, positions[P:])
        x = jnp.concatenate([prefix.astype(x_tok.dtype), x_tok], axis=1)
    else:
        positions = jnp.arange(S)
        x = embed_tokens(cfg, params, tokens, positions)

    entries = []
    for seg, seg_params in zip(cfg.segments, params["segments"]):
        windows = jnp.array(seg.windows(), dtype=jnp.int32)
        if seg.kind == "attn":
            def body(h, inp):
                p_l, w = inp
                h = constrain_tokens(h)
                h, cache = _attn_block_fwd(cfg, p_l, h, positions, w,
                                           want_cache=True, s_max=s_max)
                return h, cache
            x, caches = _scan(cfg, body, x, (seg_params, windows))
        elif seg.kind == "ssm":
            def body(h, p_l):
                h = constrain_tokens(h)
                h2 = norm_apply(cfg, p_l["norm"], h)
                y, st = ssm.forward(p_l["ssm"], h2)
                return h + y, st
            x, caches = _scan(cfg, body, x, seg_params)
        elif seg.kind == "rec":
            def body(h, p_l):
                h2 = norm_apply(cfg, p_l["norm1"], h)
                y, st = rglru.forward(p_l["rec"], h2)
                h = h + y
                h2 = norm_apply(cfg, p_l["norm2"], h)
                return h + _ffn_apply(cfg, p_l["ffn"], h2), st
            x, caches = _scan(cfg, body, x, seg_params)
        elif seg.kind == "hybrid3":
            def body(h, inp):
                p_l, w = inp
                h2 = norm_apply(cfg, p_l["rec1"]["norm1"], h)
                y, st1 = rglru.forward(p_l["rec1"]["rec"], h2)
                h = h + y
                h2 = norm_apply(cfg, p_l["rec1"]["norm2"], h)
                h = h + _ffn_apply(cfg, p_l["rec1"]["ffn"], h2)
                h2 = norm_apply(cfg, p_l["rec2"]["norm1"], h)
                y, st2 = rglru.forward(p_l["rec2"]["rec"], h2)
                h = h + y
                h2 = norm_apply(cfg, p_l["rec2"]["norm2"], h)
                h = h + _ffn_apply(cfg, p_l["rec2"]["ffn"], h2)
                h, kv = _attn_block_fwd(cfg, p_l["attn"], h, positions, w,
                                        want_cache=True, s_max=s_max)
                return h, (st1, st2, kv)
            x, caches = _scan(cfg, body, x, (seg_params, windows))
        elif seg.kind == "xattn":
            def body(h, p_l):
                h, cc = _xattn_block_fwd(cfg, p_l, h, positions, enc_out,
                                         want_cache=True, s_max=s_max)
                return h, cc
            x, caches = _scan(cfg, body, x, seg_params)
        else:
            raise ValueError(seg.kind)
        entries.append(caches)

    x = norm_apply(cfg, params["final_norm"], x)
    last = x[:, -1:, :]
    logits = logits_head(cfg, params, last)
    total = S + (prefix.shape[1] if prefix is not None else 0)
    return logits, ServeCache(tuple(entries),
                              jnp.asarray(total, jnp.int32))


def decode(cfg: LMConfig, params, token: jnp.ndarray, cache: ServeCache
           ) -> Tuple[jnp.ndarray, ServeCache]:
    """One decode step.  token (B, 1) int32 -> (logits (B,1,V), cache)."""
    cur = cache.cur_pos
    x = embed_tokens(cfg, params, token, cur[None])
    new_entries = []
    for seg, seg_params, entry in zip(cfg.segments, params["segments"],
                                      cache.entries):
        windows = jnp.array(seg.windows(), dtype=jnp.int32)
        if seg.kind == "attn":
            def body(h, inp):
                p_l, w, kv = inp
                h2 = norm_apply(cfg, p_l["norm1"], h)
                a, kv2 = attention.decode_step(
                    p_l["attn"], h2, kv, cur, window=w,
                    softcap=cfg.attn_softcap,
                    use_rope=(cfg.pos_embed == "rope"))
                h = h + a
                h2 = norm_apply(cfg, p_l["norm2"], h)
                return h + _ffn_apply(cfg, p_l["ffn"], h2), kv2
            x, new = _scan(cfg, body, x, (seg_params, windows, entry))
        elif seg.kind == "ssm":
            def body(h, inp):
                p_l, st = inp
                h2 = norm_apply(cfg, p_l["norm"], h)
                y, st2 = ssm.forward(p_l["ssm"], h2, st)
                return h + y, st2
            x, new = _scan(cfg, body, x, (seg_params, entry))
        elif seg.kind == "rec":
            def body(h, inp):
                p_l, st = inp
                h2 = norm_apply(cfg, p_l["norm1"], h)
                y, st2 = rglru.forward(p_l["rec"], h2, st)
                h = h + y
                h2 = norm_apply(cfg, p_l["norm2"], h)
                return h + _ffn_apply(cfg, p_l["ffn"], h2), st2
            x, new = _scan(cfg, body, x, (seg_params, entry))
        elif seg.kind == "hybrid3":
            def body(h, inp):
                p_l, w, (st1, st2, kv) = inp
                h2 = norm_apply(cfg, p_l["rec1"]["norm1"], h)
                y, st1n = rglru.forward(p_l["rec1"]["rec"], h2, st1)
                h = h + y
                h2 = norm_apply(cfg, p_l["rec1"]["norm2"], h)
                h = h + _ffn_apply(cfg, p_l["rec1"]["ffn"], h2)
                h2 = norm_apply(cfg, p_l["rec2"]["norm1"], h)
                y, st2n = rglru.forward(p_l["rec2"]["rec"], h2, st2)
                h = h + y
                h2 = norm_apply(cfg, p_l["rec2"]["norm2"], h)
                h = h + _ffn_apply(cfg, p_l["rec2"]["ffn"], h2)
                h2 = norm_apply(cfg, p_l["attn"]["norm1"], h)
                a, kvn = attention.decode_step(
                    p_l["attn"]["attn"], h2, kv, cur, window=w,
                    softcap=cfg.attn_softcap,
                    use_rope=(cfg.pos_embed == "rope"))
                h = h + a
                h2 = norm_apply(cfg, p_l["attn"]["norm2"], h)
                h = h + _ffn_apply(cfg, p_l["attn"]["ffn"], h2)
                return h, (st1n, st2n, kvn)
            x, new = _scan(cfg, body, x, (seg_params, windows, entry))
        elif seg.kind == "xattn":
            def body(h, inp):
                p_l, (kv_self, kv_cross) = inp
                h2 = norm_apply(cfg, p_l["norm1"], h)
                a, kv2 = attention.decode_step(
                    p_l["self"], h2, kv_self, cur,
                    use_rope=(cfg.pos_embed == "rope"))
                h = h + a
                h2 = norm_apply(cfg, p_l["norm2"], h)
                c = attention.cross_decode(p_l["cross"], h2, kv_cross)
                h = h + c
                h2 = norm_apply(cfg, p_l["norm3"], h)
                return h + _ffn_apply(cfg, p_l["ffn"], h2), (kv2, kv_cross)
            x, new = _scan(cfg, body, x, (seg_params, entry))
        else:
            raise ValueError(seg.kind)
        new_entries.append(new)

    x = norm_apply(cfg, params["final_norm"], x)
    logits = logits_head(cfg, params, x)
    return logits, ServeCache(tuple(new_entries), cur + 1)
