"""Mamba-1 selective state-space block (falcon-mamba-7b).

Structure per block (Gu & Dao 2023, falcon-mamba variant):
  in_proj: D -> 2*Di (x, z gate)
  depthwise causal conv1d (kernel 4) + SiLU on x
  selective SSM: per-channel state (Di, N); data-dependent dt, B, C:
     dt = softplus(x @ W_dt_down @ W_dt_up + bias)   (via dt_rank)
     B, C = x @ W_B, x @ W_C                         (Di -> N each)
     h_t = exp(A * dt_t) * h_{t-1} + dt_t * B_t * x_t
     y_t = (C_t . h_t) + D_skip * x_t
  gate: y * silu(z); out_proj: Di -> D

Training/prefill uses an *associative scan* over the sequence (the TPU-
native adaptation of the paper's CUDA selective-scan kernel: work-
efficient parallel scan on the VPU instead of a fused SM kernel; see
DESIGN.md hardware-adaptation).  Decode keeps (conv_state, ssm_state)
and advances one token at a time.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class SSMParams(NamedTuple):
    w_in: jnp.ndarray        # (D, 2*Di)
    conv_w: jnp.ndarray      # (K, Di) depthwise
    conv_b: jnp.ndarray      # (Di,)
    w_dt_down: jnp.ndarray   # (Di, R)
    w_dt_up: jnp.ndarray     # (R, Di)
    dt_bias: jnp.ndarray     # (Di,)
    w_bc: jnp.ndarray        # (Di, 2*N)
    a_log: jnp.ndarray       # (Di, N) — A = -exp(a_log)
    d_skip: jnp.ndarray      # (Di,)
    w_out: jnp.ndarray       # (Di, D)


class SSMState(NamedTuple):
    conv: jnp.ndarray        # (B, K-1, Di) last inputs
    h: jnp.ndarray           # (B, Di, N)


def init(key, d: int, d_inner: int, n_state: int, dt_rank: int,
         conv_k: int = 4, dtype=jnp.bfloat16) -> SSMParams:
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(d_inner)
    return SSMParams(
        w_in=(jax.random.normal(ks[0], (d, 2 * d_inner)) * s).astype(dtype),
        conv_w=(jax.random.normal(ks[1], (conv_k, d_inner)) * 0.2).astype(dtype),
        conv_b=jnp.zeros((d_inner,), dtype),
        w_dt_down=(jax.random.normal(ks[2], (d_inner, dt_rank)) * si).astype(dtype),
        w_dt_up=(jax.random.normal(ks[3], (dt_rank, d_inner))
                 * (1.0 / math.sqrt(dt_rank))).astype(dtype),
        dt_bias=jnp.full((d_inner,), -4.0, dtype),   # softplus(-4) ~ 0.018
        w_bc=(jax.random.normal(ks[4], (d_inner, 2 * n_state)) * si).astype(dtype),
        a_log=jnp.log(jnp.tile(jnp.arange(1, n_state + 1, dtype=jnp.float32),
                               (d_inner, 1))),
        d_skip=jnp.ones((d_inner,), dtype),
        w_out=(jax.random.normal(ks[5], (d_inner, d)) * si).astype(dtype),
    )


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                           ) -> jnp.ndarray:
    """x (B,S,Di), w (K,Di): causal depthwise conv along S."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i: i + x.shape[1], :] * w[i]
    return out + b


def _ssm_scan(dt: jnp.ndarray, bmat: jnp.ndarray, cmat: jnp.ndarray,
              xin: jnp.ndarray, a_log: jnp.ndarray,
              h0: jnp.ndarray | None = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Selective scan over S via associative scan.

    dt (B,S,Di), bmat/cmat (B,S,N), xin (B,S,Di), a_log (Di,N).
    Returns y (B,S,Di) and final state (B,Di,N).

    Recurrence per (channel i, state n):
        h_t = exp(-exp(a_log) * dt_t) * h_{t-1} + dt_t * B_t[n] * x_t
    which is a first-order linear recurrence  h_t = g_t h_{t-1} + u_t,
    solved with an associative scan on pairs (g, u).
    """
    A = -jnp.exp(a_log.astype(jnp.float32))                      # (Di,N)
    dt32 = dt.astype(jnp.float32)
    g = jnp.exp(dt32[..., None] * A)                             # (B,S,Di,N)
    u = (dt32 * xin.astype(jnp.float32))[..., None] * \
        bmat.astype(jnp.float32)[:, :, None, :]                  # (B,S,Di,N)
    if h0 is not None:
        # fold the carried state into the first step's u
        u = u.at[:, 0].add(g[:, 0] * h0.astype(jnp.float32))

    def combine(a, b):
        ga, ua = a
        gb, ub = b
        return (ga * gb, ub + gb * ua)

    gs, hs = jax.lax.associative_scan(combine, (g, u), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs, cmat.astype(jnp.float32))
    return y.astype(xin.dtype), hs[:, -1]


def forward(p: SSMParams, x: jnp.ndarray,
            state: SSMState | None = None
            ) -> Tuple[jnp.ndarray, SSMState]:
    """Full-sequence pass (training/prefill); x (B,S,D)."""
    B, S, D = x.shape
    Di = p.conv_b.shape[0]
    N = p.a_log.shape[1]
    xz = x @ p.w_in
    xs, z = xz[..., :Di], xz[..., Di:]
    if state is not None:
        ctx = jnp.concatenate([state.conv.astype(xs.dtype), xs], axis=1)
        conv_out = _causal_depthwise_conv(ctx, p.conv_w, p.conv_b)[:, -S:]
    else:
        conv_out = _causal_depthwise_conv(xs, p.conv_w, p.conv_b)
    xs = jax.nn.silu(conv_out)
    dt = jax.nn.softplus(
        (xs @ p.w_dt_down) @ p.w_dt_up
        + p.dt_bias.astype(jnp.float32))
    bc = xs @ p.w_bc
    bmat, cmat = bc[..., :N], bc[..., N:]
    h0 = state.h if state is not None else None
    y, h_last = _ssm_scan(dt, bmat, cmat, xs, p.a_log, h0)
    y = y + xs * p.d_skip
    y = y * jax.nn.silu(z)
    out = y @ p.w_out
    K = p.conv_w.shape[0]
    tail_src = xz[..., :Di]
    if state is not None:
        ctx_tail = jnp.concatenate([state.conv.astype(tail_src.dtype),
                                    tail_src], axis=1)
    else:
        ctx_tail = jnp.pad(tail_src, ((0, 0), (K - 1, 0), (0, 0)))
    new_state = SSMState(conv=ctx_tail[:, -(K - 1):], h=h_last)
    return out, new_state


def init_state(batch: int, d_inner: int, n_state: int, conv_k: int = 4,
               dtype=jnp.bfloat16) -> SSMState:
    return SSMState(
        conv=jnp.zeros((batch, conv_k - 1, d_inner), dtype),
        h=jnp.zeros((batch, d_inner, n_state), jnp.float32),
    )


def decode_step(p: SSMParams, x: jnp.ndarray, state: SSMState
                ) -> Tuple[jnp.ndarray, SSMState]:
    """One-token decode; x (B,1,D)."""
    return forward(p, x, state)
