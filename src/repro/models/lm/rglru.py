"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrent block structure (per Griffin):
  two branches from x:
    branch 1: linear D->Di, GeLU
    branch 2: linear D->Di, causal depthwise conv1d (k=4), RG-LRU
  merge: elementwise product, linear Di->D.

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
  a_t = a^(c * r_t)            with a = sigmoid(Lambda), c = 8
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

First-order linear recurrence -> associative scan (TPU-native parallel
scan; same hardware adaptation as the SSM block).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

_C = 8.0


class RGLRUParams(NamedTuple):
    w_x: jnp.ndarray       # (D, Di)  branch-2 input proj
    w_y: jnp.ndarray       # (D, Di)  branch-1 (gelu gate) proj
    conv_w: jnp.ndarray    # (K, Di)
    conv_b: jnp.ndarray    # (Di,)
    w_r: jnp.ndarray       # (Di, Di) recurrence gate (block-diag in the
    w_i: jnp.ndarray       # (Di, Di) paper; dense here)
    lam: jnp.ndarray       # (Di,)    Lambda
    w_out: jnp.ndarray     # (Di, D)


class RGLRUState(NamedTuple):
    conv: jnp.ndarray      # (B, K-1, Di)
    h: jnp.ndarray         # (B, Di) f32


def init(key, d: int, d_inner: int, conv_k: int = 4,
         dtype=jnp.bfloat16) -> RGLRUParams:
    ks = jax.random.split(key, 6)
    s, si = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_inner)
    return RGLRUParams(
        w_x=(jax.random.normal(ks[0], (d, d_inner)) * s).astype(dtype),
        w_y=(jax.random.normal(ks[1], (d, d_inner)) * s).astype(dtype),
        conv_w=(jax.random.normal(ks[2], (conv_k, d_inner)) * 0.2).astype(dtype),
        conv_b=jnp.zeros((d_inner,), dtype),
        w_r=(jax.random.normal(ks[3], (d_inner, d_inner)) * si).astype(dtype),
        w_i=(jax.random.normal(ks[4], (d_inner, d_inner)) * si).astype(dtype),
        lam=jnp.full((d_inner,), 2.0, jnp.float32),   # sigmoid(2)~0.88
        w_out=(jax.random.normal(ks[5], (d_inner, d)) * si).astype(dtype),
    )


def _causal_conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i: i + x.shape[1], :] * w[i]
    return out + b


def _rglru_scan(x: jnp.ndarray, r: jnp.ndarray, i: jnp.ndarray,
                lam: jnp.ndarray, h0: jnp.ndarray | None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x/r/i (B,S,Di) -> y (B,S,Di), final h (B,Di)."""
    a_base = jax.nn.sigmoid(lam)                              # (Di,)
    log_a = _C * r.astype(jnp.float32) * jnp.log(a_base)      # (B,S,Di)
    a = jnp.exp(log_a)
    gated = i.astype(jnp.float32) * x.astype(jnp.float32)
    u = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    if h0 is not None:
        u = u.at[:, 0].add(a[:, 0] * h0)

    def combine(p, q):
        ap, up = p
        aq, uq = q
        return (ap * aq, uq + aq * up)

    _, hs = jax.lax.associative_scan(combine, (a, u), axis=1)
    return hs.astype(x.dtype), hs[:, -1]


def forward(p: RGLRUParams, x: jnp.ndarray,
            state: RGLRUState | None = None
            ) -> Tuple[jnp.ndarray, RGLRUState]:
    B, S, D = x.shape
    Di = p.conv_b.shape[0]
    y_gate = jax.nn.gelu((x @ p.w_y).astype(jnp.float32)).astype(x.dtype)
    xs = x @ p.w_x
    if state is not None:
        ctx = jnp.concatenate([state.conv.astype(xs.dtype), xs], axis=1)
        conv_out = _causal_conv(ctx, p.conv_w, p.conv_b)[:, -S:]
    else:
        conv_out = _causal_conv(xs, p.conv_w, p.conv_b)
    r = jax.nn.sigmoid((conv_out @ p.w_r).astype(jnp.float32))
    i = jax.nn.sigmoid((conv_out @ p.w_i).astype(jnp.float32))
    h0 = state.h if state is not None else None
    y, h_last = _rglru_scan(conv_out, r, i, p.lam, h0)
    out = (y * y_gate) @ p.w_out
    K = p.conv_w.shape[0]
    if state is not None:
        ctx_tail = jnp.concatenate([state.conv.astype(xs.dtype), xs], axis=1)
    else:
        ctx_tail = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    return out, RGLRUState(conv=ctx_tail[:, -(K - 1):], h=h_last)


def init_state(batch: int, d_inner: int, conv_k: int = 4,
               dtype=jnp.bfloat16) -> RGLRUState:
    return RGLRUState(conv=jnp.zeros((batch, conv_k - 1, d_inner), dtype),
                      h=jnp.zeros((batch, d_inner), jnp.float32))
