"""MLP blocks: gated (SiLU/GeLU-GLU, llama/gemma-style) and plain
two-matrix (whisper/GPT-style), plus RMSNorm / LayerNorm."""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class GatedMLP(NamedTuple):
    w_gate: jnp.ndarray   # (D, F)
    w_up: jnp.ndarray     # (D, F)
    w_down: jnp.ndarray   # (F, D)


class PlainMLP(NamedTuple):
    w_in: jnp.ndarray     # (D, F)
    b_in: jnp.ndarray
    w_out: jnp.ndarray    # (F, D)
    b_out: jnp.ndarray


def init_gated(key, d: int, f: int, dtype=jnp.bfloat16) -> GatedMLP:
    k1, k2, k3 = jax.random.split(key, 3)
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return GatedMLP(
        (jax.random.normal(k1, (d, f)) * s).astype(dtype),
        (jax.random.normal(k2, (d, f)) * s).astype(dtype),
        (jax.random.normal(k3, (f, d)) * so).astype(dtype),
    )


def init_plain(key, d: int, f: int, dtype=jnp.bfloat16) -> PlainMLP:
    k1, k2 = jax.random.split(key)
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return PlainMLP(
        (jax.random.normal(k1, (d, f)) * s).astype(dtype),
        jnp.zeros((f,), dtype),
        (jax.random.normal(k2, (f, d)) * so).astype(dtype),
        jnp.zeros((d,), dtype),
    )


def gated(p: GatedMLP, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    g = x @ p.w_gate
    u = x @ p.w_up
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return (g * u) @ p.w_down


def plain(p: PlainMLP, x: jnp.ndarray, act: str = "gelu") -> jnp.ndarray:
    h = x @ p.w_in + p.b_in
    h = jax.nn.gelu(h) if act == "gelu" else jax.nn.relu(h)
    return h @ p.w_out + p.b_out


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def rmsnorm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)
