"""Sharding rules: map parameter/activation pytrees to NamedShardings.

Semantic TP rules (Megatron-style):
* column-parallel producing weights  — attention heads (wq on H, wk/wv on
  KV), FFN hidden (w_gate/w_up on F), experts (on E);
* row-parallel consuming weights     — wo (on H), w_down (on F / E);
* embeddings vocab-sharded (falls back to d_model when vocab doesn't
  divide the axis);
* SSM/RG-LRU channel dims (Di) model-sharded (the recurrences are
  per-channel, so the scan shards cleanly);
* norms/biases/scalars replicated.

When ``cfg.fsdp`` is set, the largest remaining divisible dim is
additionally sharded over the data axes (ZeRO-3-style parameter +
optimizer-state sharding; GSPMD turns the gradient all-reduces into
reduce-scatters and all-gathers weights just-in-time).

Every emitted spec passes a divisibility guard: an axis that does not
divide its dim is dropped (replicated) rather than producing an
unshardable program.  Scan-stacked parameters (under ``segments``) keep
their leading layer dim unsharded.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig


def _path_names(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _guard(mesh: Mesh, shape: Tuple[int, ...], spec: Sequence) -> P:
    """Drop axes that don't divide their dim."""
    fixed = []
    for dim, axes in zip(shape, spec):
        if axes is None:
            fixed.append(None)
            continue
        ax = (axes,) if isinstance(axes, str) else tuple(axes)
        keep = []
        size = dim
        for a in ax:
            s = mesh.shape[a]
            if size % s == 0:
                keep.append(a)
                size //= s
        fixed.append(tuple(keep) if len(keep) > 1 else
                     (keep[0] if keep else None))
    return P(*fixed)


def _with_fsdp(spec: list, shape: Tuple[int, ...], mesh: Mesh,
               dp_axes, enabled: bool) -> list:
    """Shard the largest still-unsharded divisible dim over data axes."""
    if not enabled:
        return spec
    dp = _axis_size(mesh, dp_axes)
    best, best_dim = None, 0
    for i, (dim, axes) in enumerate(zip(shape, spec)):
        if axes is None and dim % dp == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best is not None:
        spec = list(spec)
        spec[best] = dp_axes
    return spec


def param_pspec(cfg: LMConfig, mesh: Mesh, path, leaf) -> P:
    names = _path_names(path)
    name = names[-1]
    stacked = any(n in ("segments", "enc_segments") for n in names)
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    shape = leaf.shape
    core = shape[1:] if stacked else shape
    m = "model"

    def build(spec_core: list, fsdp_dims: bool = True) -> P:
        spec_core = _with_fsdp(spec_core, core, mesh, dp,
                               cfg.fsdp and fsdp_dims)
        spec = ([None] + list(spec_core)) if stacked else list(spec_core)
        return _guard(mesh, shape, spec)

    # ---- embeddings / heads -------------------------------------------
    if name == "embed":                       # (V, D)
        if shape[0] % _axis_size(mesh, m) == 0:
            return build([m, None])
        return build([None, m])
    if name == "lm_head":                     # (D, V)
        if shape[-1] % _axis_size(mesh, m) == 0:
            return build([None, m])
        return build([m, None])

    # ---- attention ------------------------------------------------------
    if name == "wq":                          # (D, H, hd)
        return build([None, m, None])
    if name in ("wk", "wv"):                  # (D, KV, hd)
        return build([None, m, None])
    if name == "wo":                          # (H, hd, D)
        return build([m, None, None])

    # ---- dense FFN ------------------------------------------------------
    if name in ("w_gate", "w_up"):
        if len(core) == 3:                    # MoE (E, D, F)
            if core[0] % _axis_size(mesh, m) == 0:
                return build([m, None, None])
            return build([None, None, m])
        return build([None, m])               # (D, F)
    if name == "w_down":
        if len(core) == 3:                    # MoE (E, F, D)
            if core[0] % _axis_size(mesh, m) == 0:
                return build([m, None, None])
            return build([None, m, None])
        return build([m, None])               # (F, D)
    if name in ("w_in", "b_in"):              # plain MLP (D, F)/(F,)
        if len(core) == 2:
            return build([None, m])
        return build([m])
    if name in ("w_out", "b_out"):
        if name == "w_out" and len(core) == 2:
            return build([m, None])           # (F|Di, D)
        return build([None] * len(core), fsdp_dims=False)
    if name == "w_router":                    # (D, E) — replicated, f32
        return build([None, None], fsdp_dims=False)

    # ---- SSM / RG-LRU ---------------------------------------------------
    if name in ("conv_w",):                   # (K, Di)
        return build([None, m])
    if name in ("conv_b", "dt_bias", "d_skip", "lam"):
        return build([m])
    if name in ("w_dt_down", "w_bc", "a_log", "w_r", "w_i"):  # (Di, *)
        return build([m, None])
    if name == "w_dt_up":                     # (R, Di)
        return build([None, m])
    if name in ("w_x", "w_y"):                # (D, Di)
        return build([None, m])

    # ---- norms & everything else: replicated -----------------------------
    return build([None] * len(core), fsdp_dims=False)


def param_shardings(cfg: LMConfig, mesh: Mesh, abstract_params):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(cfg, mesh, path, leaf)),
        abstract_params)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, shape: Tuple[int, ...]) -> P:
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    return _guard(mesh, shape, [dp] + [None] * (len(shape) - 1))


def batch_shardings(mesh: Mesh, batch_spec: Dict[str, jax.ShapeDtypeStruct]):
    return {k: NamedSharding(mesh, batch_pspec(mesh, v.shape))
            for k, v in batch_spec.items()}


def cache_pspec(mesh: Mesh, path, leaf) -> P:
    """Stacked cache entries (L, B, S, KV, hd) / states (L, B, ...).

    Preference: batch over data axes; KV heads over model; if KV doesn't
    divide, shard the sequence dim over model (decode attention reduces
    over S with collectives); long-context batch-1 shapes shard S over
    both data and model.
    """
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    shape = leaf.shape
    if len(shape) == 5:                     # KV cache (L,B,S,KV,hd)
        L, B, S, KV, hd = shape
        spec: list = [None, dp, None, "model", None]
        if KV % mesh.shape["model"] != 0:
            spec[3] = None
            spec[2] = "model"
        if B < _axis_size(mesh, dp):
            spec[1] = None
            # push data axes onto sequence as well
            cur = spec[2]
            if cur is None:
                spec[2] = dp
            else:
                spec[2] = tuple(list(dp) + [cur])
        return _guard(mesh, shape, spec)
    if len(shape) >= 2:                     # states (L,B,...) / (L,B,Di,N)
        spec = [None, dp] + [None] * (len(shape) - 2)
        if len(shape) >= 3 and shape[1] < _axis_size(mesh, dp):
            spec[1] = None
            spec[2] = "model" if shape[2] % mesh.shape["model"] == 0 else None
        return _guard(mesh, shape, spec)
    return P()


def cache_shardings(mesh: Mesh, abstract_cache):
    def rule(path, leaf):
        if leaf.shape == ():                # cur_pos scalar
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, cache_pspec(mesh, path, leaf))
    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


def opt_shardings(cfg: LMConfig, mesh: Mesh, abstract_opt, abstract_params):
    """m/v mirror the param shardings; step replicated."""
    pshard = param_shardings(cfg, mesh, abstract_params)
    from repro.optim.adamw import AdamWState
    return AdamWState(step=NamedSharding(mesh, P()), m=pshard,
                      v=jax.tree_util.tree_map(lambda s: s, pshard))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# activation-constraint context (set by launchers; no-op on bare CPU)
# ---------------------------------------------------------------------------

import contextlib as _contextlib  # noqa: E402  (section-local helper deps)
import contextvars as _contextvars  # noqa: E402

_ACT_MESH: "_contextvars.ContextVar" = _contextvars.ContextVar(
    "repro_activation_mesh", default=None)


@_contextlib.contextmanager
def activation_mesh(mesh):
    """While active, ``constrain`` pins activation shardings to ``mesh``.
    Launchers (dryrun/train/serve) wrap tracing in this; tests and
    single-device runs skip it and ``constrain`` is a no-op."""
    tok = _ACT_MESH.set(mesh)
    try:
        yield
    finally:
        _ACT_MESH.reset(tok)


def dp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def constrain(x, spec_fn):
    """Apply with_sharding_constraint if an activation mesh is active.

    ``spec_fn(mesh, dp)`` returns a PartitionSpec-able list for x (use
    None entries freely); axes that don't divide are dropped by _guard.
    """
    mesh = _ACT_MESH.get()
    if mesh is None or x is None:
        return x
    dp = dp_axes(mesh)
    spec = spec_fn(mesh, dp)
    guarded = _guard(mesh, x.shape, spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, guarded))


def constrain_tokens(x):
    """Residual stream (B, S, D): batch over data axes."""
    return constrain(x, lambda mesh, dp: [dp] + [None] * (x.ndim - 1))


def constrain_moe_slots(x):
    """MoE dispatch slots (B, E, C, D): batch->data, experts->model."""
    return constrain(x, lambda mesh, dp: [dp, "model", None, None])
