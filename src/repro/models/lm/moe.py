"""Mixture-of-Experts layer with capacity-based top-k dispatch.

Design (MaxText/GShard-style, SPMD-friendly, honest FLOPs):

* router: (B, S, D) @ (D, E) -> top-k experts per token (softmax over the
  selected logits, qwen/granite convention).
* dispatch: per-sequence grouping.  Every sequence routes its S tokens
  into E expert bins with fixed capacity C = S*k/E * capacity_factor
  (tokens beyond capacity are dropped — their combine weight is zero).
  Slot assignment uses a cumulative-count ("position in expert") scheme;
  gathering produces (B, E, C, D) without giant one-hot einsums.
* experts: stacked weights (E, D, F)x2 gate/up + (E, F, D) down; batched
  einsum => FLOPs = B*E*C*(3*D*F)*2 ~= tokens * k * cf * expert_flops —
  the *active*-parameter compute, not the dense-all-experts blowup.
* combine: scatter-add back to (B, S, D) weighted by router gates.

Sharding: experts dim E -> "model" (expert parallelism); batch B ->
("pod","data").  GSPMD inserts the dispatch all-to-alls.

A dense reference (`dense_forward`) computes every expert for every token
and is used to validate the capacity path in tests (with cf high enough
that nothing drops, the two must agree to float tolerance).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .sharding import constrain_moe_slots, constrain_tokens


class MoEParams(NamedTuple):
    w_router: jnp.ndarray   # (D, E) float32 for routing stability
    w_gate: jnp.ndarray     # (E, D, F)
    w_up: jnp.ndarray       # (E, D, F)
    w_down: jnp.ndarray     # (E, F, D)


def init(key, d: int, f: int, n_experts: int, dtype=jnp.bfloat16) -> MoEParams:
    k0, k1, k2, k3 = jax.random.split(key, 4)
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return MoEParams(
        w_router=jax.random.normal(k0, (d, n_experts)).astype(jnp.float32) * s,
        w_gate=(jax.random.normal(k1, (n_experts, d, f)) * s).astype(dtype),
        w_up=(jax.random.normal(k2, (n_experts, d, f)) * s).astype(dtype),
        w_down=(jax.random.normal(k3, (n_experts, f, d)) * so).astype(dtype),
    )


def capacity(seq_len: int, n_experts: int, top_k: int,
             capacity_factor: float = 1.25) -> int:
    c = int(math.ceil(seq_len * top_k / n_experts * capacity_factor))
    return max(c, top_k)


def route(p: MoEParams, x: jnp.ndarray, top_k: int
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (gates (B,S,k) f32 normalized, experts (B,S,k) int32)."""
    logits = x.astype(jnp.float32) @ p.w_router
    top_vals, top_idx = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    return gates, top_idx


#: routing-group length: capacity is per contiguous token group, and all
#: dispatch buffers are sized by the group, not the full sequence
GROUP = 4096


def forward(p: MoEParams, x: jnp.ndarray, top_k: int,
            capacity_factor: float = 1.25, act: str = "silu") -> jnp.ndarray:
    """Capacity-based top-k MoE; x (B, S, D).

    Gather-only dispatch (SPMD-friendly — no giant scatters, which GSPMD
    replicates):
      1. tokens regrouped to (B*n_g, G, D);
      2. per group: top-k routing; position-in-expert via cumsum;
      3. dispatch = *gather* from x with per-slot source-token indices
         (sentinel-padded), giving (B', E, C, D);
      4. batched expert GLU einsums (E model-sharded = EP);
      5. combine = k separate gathers of (B', G, D) weighted by gates —
         never materializing a (B', G*k, D) expansion.
    """
    B, S, D = x.shape
    E = p.w_router.shape[1]
    G = min(GROUP, S)
    n_g = S // G if S % G == 0 else 1
    if S % G != 0:
        G = S
    Bp = B * n_g
    xg = x.reshape(Bp, G, D)
    C = capacity(G, E, top_k, capacity_factor)
    gates, experts = route(p, xg, top_k)                      # (B',G,k)

    # --- position of each (token, choice) within its expert --------------
    flat_e = experts.reshape(Bp, G * top_k)                   # (B', G*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - onehot,
                              flat_e[..., None], axis=-1)[..., 0]
    keep = pos < C                                            # (B', G*k)

    # --- per-slot source token: scatter small indices, gather tokens ------
    # slot (e, c) <- token index  (sentinel G when unfilled); dropped
    # assignments write to a dump slot at E*C so they never clobber slot 0
    slot = flat_e * C + jnp.where(keep, pos, 0)
    token_idx = jnp.arange(G * top_k, dtype=jnp.int32) // top_k
    src = jnp.full((Bp, E * C + 1), G, jnp.int32)             # sentinel
    src = src.at[jnp.arange(Bp)[:, None],
                 jnp.where(keep, slot, E * C)].set(
        jnp.where(keep, token_idx[None, :], G))[:, : E * C]
    x_pad = jnp.concatenate([xg, jnp.zeros((Bp, 1, D), xg.dtype)], axis=1)
    slots = jnp.take_along_axis(
        x_pad, src[..., None], axis=1).reshape(Bp, E, C, D)
    slots = constrain_moe_slots(slots)

    # --- experts: batched gated MLP (E model-sharded) ---------------------
    g = jnp.einsum("becd,edf->becf", slots, p.w_gate)
    u = jnp.einsum("becd,edf->becf", slots, p.w_up)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    y = jnp.einsum("becf,efd->becd", g * u, p.w_down)         # (B',E,C,D)
    y_flat = y.reshape(Bp, E * C, D)

    # --- combine: one bounded gather per routing choice -------------------
    out = jnp.zeros((Bp, G, D), y.dtype)
    slot_k = slot.reshape(Bp, G, top_k)
    keep_k = keep.reshape(Bp, G, top_k)
    for kk in range(top_k):
        yk = jnp.take_along_axis(y_flat, slot_k[:, :, kk][..., None],
                                 axis=1)                      # (B', G, D)
        wk = (gates[:, :, kk] * keep_k[:, :, kk]).astype(y.dtype)
        out = out + yk * wk[..., None]
    return constrain_tokens(out.reshape(B, S, D))


def dense_forward(p: MoEParams, x: jnp.ndarray, top_k: int,
                  act: str = "silu") -> jnp.ndarray:
    """Reference: run every expert on every token (oracle for tests)."""
    B, S, D = x.shape
    E = p.w_router.shape[1]
    gates, experts = route(p, x, top_k)
    # scatter top-k gates into dense (B,S,E)
    dense_gates = jnp.zeros((B, S, E), jnp.float32).at[
        jnp.arange(B)[:, None, None],
        jnp.arange(S)[None, :, None],
        experts,
    ].set(gates)
    g = jnp.einsum("bsd,edf->bsef", x, p.w_gate)
    u = jnp.einsum("bsd,edf->bsef", x, p.w_up)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    y = jnp.einsum("bsef,efd->bsed", g * u, p.w_down)
    return jnp.einsum("bsed,bse->bsd", y, dense_gates.astype(y.dtype))
