"""Executable ResNet8 / ResNet18-CIFAR (the paper's §V.A/§V.B workloads).

* **ResNet8** — the MLPerf-Tiny CIFAR-10 ResNet: stem conv(16) + three
  stages of one basic block each (16/32/64, stride 1/2/2, 1x1 downsample
  convs in stages 2-3) + GAP + fc.  9 convs + 1 fc = the paper's "14 nodes
  total, 10 of which are convolutional"; ~78K parameters.

* **ResNet18-CIFAR** — standard ResNet18 with 3x3 stem (no maxpool) and
  width halved to (32,64,128,256) so the total is 2.79M ~ the paper's
  "2.8M parameters"; 20 convs + 1 fc + 8 adds + 1 GAP = 30 nodes, and the
  topological numbering of IMC nodes reproduces the paper's Table I id
  set exactly (checked in tests).
"""

from __future__ import annotations

from typing import Dict

try:
    import jax
    import jax.numpy as jnp
except ModuleNotFoundError:  # arch specs stay importable without jax
    jax = jnp = None  # type: ignore[assignment]

from . import layers as L


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

RESNET8 = {
    "name": "resnet8",
    "stem_width": 16,
    "stage_widths": (16, 32, 64),
    "blocks_per_stage": (1, 1, 1),
    "num_classes": 10,
    "image_hw": (32, 32),
}

RESNET18_CIFAR = {
    "name": "resnet18_cifar",
    "stem_width": 32,
    "stage_widths": (32, 64, 128, 256),
    "blocks_per_stage": (2, 2, 2, 2),
    "num_classes": 10,
    "image_hw": (32, 32),
}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(key, cfg: dict) -> Dict:
    """Parameter pytree mirroring the block structure."""
    keys = iter(jax.random.split(key, 64))
    params: Dict = {"stem": L.conv_init(next(keys), 3, 3, cfg["stem_width"])}
    cin = cfg["stem_width"]
    stages = []
    for si, (width, nblocks) in enumerate(
        zip(cfg["stage_widths"], cfg["blocks_per_stage"])
    ):
        blocks = []
        for bi in range(nblocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            block = {
                "conv1": L.conv_init(next(keys), 3, cin, width),
                "conv2": L.conv_init(next(keys), 3, width, width),
            }
            if stride != 1 or cin != width:
                block["down"] = L.conv_init(next(keys), 1, cin, width)
            blocks.append(block)
            cin = width
        stages.append(blocks)
    params["stages"] = stages
    params["fc"] = L.dense_init(next(keys), cin, cfg["num_classes"])
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(params: Dict, x: jnp.ndarray, cfg: dict) -> jnp.ndarray:
    """NHWC image batch -> logits."""
    x = L.conv2d(params["stem"], x, stride=1, act="relu")
    for si, blocks in enumerate(params["stages"]):
        for bi, block in enumerate(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            identity = x
            y = L.conv2d(block["conv1"], x, stride=stride, act="relu")
            y = L.conv2d(block["conv2"], y, stride=1, act=None)
            if "down" in block:
                identity = L.conv2d(block["down"], identity, stride=stride,
                                    act=None)
            x = jax.nn.relu(y + identity)
    x = L.global_avg_pool(x)
    return L.dense(params["fc"], x)


def num_params(cfg: dict) -> int:
    return L.count_params(init(jax.random.PRNGKey(0), cfg))
