"""Executable YOLOv8n (the paper's §V.C workload) in pure JAX.

Standard ultralytics YOLOv8n topology at width 0.25 / depth 0.33:
backbone (P1..P5 + SPPF), PAN neck, decoupled Detect head with DFL
decoding.  ~3.16M parameters (paper: "3.17M").  The deployment graph
(`graphs.build_yolov8n_graph`) mirrors this model at ONNX-node
granularity: 233 nodes, 63 convolutional, 57 followed by SiLU — the
paper's exact counts (asserted in tests).

The "3 parallel main branches" the paper describes are the three
detection scales (P3/P4/P5) flowing through the neck: each has one long
sub-branch (C2f path: cv1 + 2 bottleneck convs + cv2 = 5 conv chain) and
two short ones (the 3-conv box/cls head branches).
"""

from __future__ import annotations

from typing import Dict, Tuple

try:
    import jax
    import jax.numpy as jnp
except ModuleNotFoundError:  # arch specs stay importable without jax
    jax = jnp = None  # type: ignore[assignment]

from . import layers as L

# width-scaled channel plan for v8n
CH = {"p1": 16, "p2": 32, "p3": 64, "p4": 128, "p5": 256}
NC = 80              # COCO classes
REG_MAX = 16         # DFL bins
STRIDES = (8, 16, 32)

YOLOV8N = {
    "name": "yolov8n",
    "image_hw": (640, 640),
    "nc": NC,
    "reg_max": REG_MAX,
}


# ---------------------------------------------------------------------------
# modules
# ---------------------------------------------------------------------------

def _conv_module_init(key, k, cin, cout):
    """Conv + folded-BN + SiLU ("Conv" module in ultralytics)."""
    return L.conv_init(key, k, cin, cout)


def _bottleneck_init(key, c):
    k1, k2 = jax.random.split(key)
    return {"cv1": _conv_module_init(k1, 3, c, c),
            "cv2": _conv_module_init(k2, 3, c, c)}


def _c2f_init(key, cin, cout, n):
    keys = jax.random.split(key, n + 2)
    c = cout // 2
    return {
        "cv1": _conv_module_init(keys[0], 1, cin, cout),
        "m": [_bottleneck_init(keys[i + 1], c) for i in range(n)],
        "cv2": _conv_module_init(keys[-1], 1, (2 + n) * c, cout),
    }


def _sppf_init(key, c):
    k1, k2 = jax.random.split(key)
    return {"cv1": _conv_module_init(k1, 1, c, c // 2),
            "cv2": _conv_module_init(k2, 1, 2 * c, c)}


def _detect_init(key, chs: Tuple[int, ...]):
    c2 = max(16, chs[0] // 4, 4 * REG_MAX)      # 64 for v8n
    c3 = max(chs[0], min(NC, 100))              # 80 for v8n
    keys = iter(jax.random.split(key, 64))
    head = {"cv2": [], "cv3": []}
    for c in chs:
        head["cv2"].append({
            "0": _conv_module_init(next(keys), 3, c, c2),
            "1": _conv_module_init(next(keys), 3, c2, c2),
            "2": L.conv_init(next(keys), 1, c2, 4 * REG_MAX),   # plain conv
        })
        head["cv3"].append({
            "0": _conv_module_init(next(keys), 3, c, c3),
            "1": _conv_module_init(next(keys), 3, c3, c3),
            "2": L.conv_init(next(keys), 1, c3, NC),            # plain conv
        })
    return head


def init(key, cfg: dict = YOLOV8N) -> Dict:
    keys = iter(jax.random.split(key, 32))
    p = {}
    p["b0"] = _conv_module_init(next(keys), 3, 3, CH["p1"])
    p["b1"] = _conv_module_init(next(keys), 3, CH["p1"], CH["p2"])
    p["b2"] = _c2f_init(next(keys), CH["p2"], CH["p2"], 1)
    p["b3"] = _conv_module_init(next(keys), 3, CH["p2"], CH["p3"])
    p["b4"] = _c2f_init(next(keys), CH["p3"], CH["p3"], 2)
    p["b5"] = _conv_module_init(next(keys), 3, CH["p3"], CH["p4"])
    p["b6"] = _c2f_init(next(keys), CH["p4"], CH["p4"], 2)
    p["b7"] = _conv_module_init(next(keys), 3, CH["p4"], CH["p5"])
    p["b8"] = _c2f_init(next(keys), CH["p5"], CH["p5"], 1)
    p["b9"] = _sppf_init(next(keys), CH["p5"])
    # neck
    p["n12"] = _c2f_init(next(keys), CH["p4"] + CH["p5"], CH["p4"], 1)
    p["n15"] = _c2f_init(next(keys), CH["p3"] + CH["p4"], CH["p3"], 1)
    p["n16"] = _conv_module_init(next(keys), 3, CH["p3"], CH["p3"])
    p["n18"] = _c2f_init(next(keys), CH["p3"] + CH["p4"], CH["p4"], 1)
    p["n19"] = _conv_module_init(next(keys), 3, CH["p4"], CH["p4"])
    p["n21"] = _c2f_init(next(keys), CH["p4"] + CH["p5"], CH["p5"], 1)
    p["head"] = _detect_init(next(keys), (CH["p3"], CH["p4"], CH["p5"]))
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _conv(p, x, stride=1, act="silu", k=None):
    return L.conv2d(p, x, stride=stride, act=act)


def _c2f(p, x, shortcut: bool):
    y = _conv(p["cv1"], x)
    a, b = jnp.split(y, 2, axis=-1)
    chunks = [a, b]
    h = b
    for bn in p["m"]:
        out = _conv(bn["cv2"], _conv(bn["cv1"], h))
        h = h + out if shortcut else out
        chunks.append(h)
    return _conv(p["cv2"], jnp.concatenate(chunks, axis=-1))


def _sppf(p, x):
    y = _conv(p["cv1"], x)
    p1 = L.max_pool(y, 5, stride=1, padding="SAME")
    p2 = L.max_pool(p1, 5, stride=1, padding="SAME")
    p3 = L.max_pool(p2, 5, stride=1, padding="SAME")
    return _conv(p["cv2"], jnp.concatenate([y, p1, p2, p3], axis=-1))


def backbone_neck(params, x):
    """Returns the three scale features (P3, P4, P5)."""
    x = _conv(params["b0"], x, stride=2)
    x = _conv(params["b1"], x, stride=2)
    x = _c2f(params["b2"], x, shortcut=True)
    x = _conv(params["b3"], x, stride=2)
    p3 = _c2f(params["b4"], x, shortcut=True)
    x = _conv(params["b5"], p3, stride=2)
    p4 = _c2f(params["b6"], x, shortcut=True)
    x = _conv(params["b7"], p4, stride=2)
    x = _c2f(params["b8"], x, shortcut=True)
    p5 = _sppf(params["b9"], x)
    # PAN neck
    u1 = L.upsample_nearest(p5)
    n12 = _c2f(params["n12"], jnp.concatenate([u1, p4], axis=-1), shortcut=False)
    u2 = L.upsample_nearest(n12)
    n15 = _c2f(params["n15"], jnp.concatenate([u2, p3], axis=-1), shortcut=False)
    d1 = _conv(params["n16"], n15, stride=2)
    n18 = _c2f(params["n18"], jnp.concatenate([d1, n12], axis=-1), shortcut=False)
    d2 = _conv(params["n19"], n18, stride=2)
    n21 = _c2f(params["n21"], jnp.concatenate([d2, p5], axis=-1), shortcut=False)
    return n15, n18, n21


def _head_branch(branch, x):
    y = _conv(branch["0"], x)
    y = _conv(branch["1"], y)
    return L.conv2d(branch["2"], y, act=None)   # plain conv, no act


def forward(params, x, cfg: dict = YOLOV8N, decode: bool = True):
    """NHWC image -> (B, anchors, 4+NC) decoded predictions (or raw per-
    scale outputs with decode=False)."""
    feats = backbone_neck(params, x)
    raw = []
    for i, f in enumerate(feats):
        box = _head_branch(params["head"]["cv2"][i], f)
        cls = _head_branch(params["head"]["cv3"][i], f)
        raw.append(jnp.concatenate([box, cls], axis=-1))
    if not decode:
        return raw

    # DFL decode + dist2bbox (the 24 post-processing ONNX nodes)
    b = x.shape[0]
    flat, anchors, strides = [], [], []
    for f, s in zip(raw, STRIDES):
        _, h, w, c = f.shape
        flat.append(f.reshape(b, h * w, c))
        ys, xs = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
        anchors.append(jnp.stack([xs.reshape(-1) + 0.5, ys.reshape(-1) + 0.5], -1))
        strides.append(jnp.full((h * w, 1), float(s)))
    z = jnp.concatenate(flat, axis=1)
    anchor = jnp.concatenate(anchors, axis=0)
    stride = jnp.concatenate(strides, axis=0)
    box, cls = z[..., : 4 * REG_MAX], z[..., 4 * REG_MAX:]
    # DFL: softmax over bins, expectation via fixed conv [0..15]
    box = box.reshape(b, -1, 4, REG_MAX)
    box = jax.nn.softmax(box, axis=-1) @ jnp.arange(REG_MAX, dtype=jnp.float32)
    lt, rb = box[..., :2], box[..., 2:]
    x1y1 = anchor - lt
    x2y2 = anchor + rb
    cxy = (x1y1 + x2y2) / 2.0
    wh = x2y2 - x1y1
    bbox = jnp.concatenate([cxy, wh], axis=-1) * stride
    return jnp.concatenate([bbox, jax.nn.sigmoid(cls)], axis=-1)


def num_params(cfg: dict = YOLOV8N) -> int:
    return L.count_params(init(jax.random.PRNGKey(0), cfg))
