"""Deployment-graph builders for the paper's CNN workloads.

Each builder mirrors the corresponding executable model one-to-one and
emits a ``repro.core.Graph`` whose nodes carry:

* scheduling cost metadata (flops, weight_bytes, out_bytes/elems, IMC
  tiling meta) consumed by ``repro.core.cost.CostModel``;
* execution metadata (``meta["param"]`` path into the model's parameter
  pytree + op attributes) consumed by ``repro.models.cnn.executor`` so a
  scheduled graph remains a *runnable program*, not just a cost table.

Node numbering is topological and matches the paper's Table I ids for
ResNet18-CIFAR (verified in tests/test_cnn_graphs.py).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from repro.core.graph import Graph, OpKind

from . import layers as L
from .resnet import RESNET8, RESNET18_CIFAR
from .yolo import CH, NC, REG_MAX, YOLOV8N


def _add_conv(g: Graph, name: str, deps: List[int], h: int, w: int, k: int,
              cin: int, cout: int, stride: int, act: Optional[str],
              param: tuple, padding: str = "SAME") -> Tuple[int, int, int]:
    cost = L.conv_cost(h, w, k, cin, cout, stride, padding)
    meta = dict(cost.pop("meta"))
    meta.update(param=param, stride=stride, act=act, padding=padding, k=k)
    n = g.add(name, OpKind.CONV, deps=deps, fused_act=act, meta=meta, **cost)
    ho, wo = meta["out_hw"]
    return n.node_id, ho, wo


def build_resnet_graph(cfg: dict) -> Graph:
    """Deployment DAG for either ResNet variant (no INPUT/OUTPUT glue —
    the paper's node counts include compute nodes only)."""
    g = Graph(cfg["name"])
    h, w = cfg["image_hw"]
    cin = 3

    nid, h, w = _add_conv(g, "stem", [], h, w, 3, cin, cfg["stem_width"], 1,
                          "relu", ("stem",))
    cin = cfg["stem_width"]
    prev = nid

    for si, (width, nblocks) in enumerate(
        zip(cfg["stage_widths"], cfg["blocks_per_stage"])
    ):
        for bi in range(nblocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            needs_down = stride != 1 or cin != width
            identity = prev
            c1, h1, w1 = _add_conv(
                g, f"s{si}b{bi}.conv1", [prev], h, w, 3, cin, width, stride,
                "relu", ("stages", si, bi, "conv1"))
            c2, h2, w2 = _add_conv(
                g, f"s{si}b{bi}.conv2", [c1], h1, w1, 3, width, width, 1,
                None, ("stages", si, bi, "conv2"))
            add_deps = [c2]
            if needs_down:
                d, _, _ = _add_conv(
                    g, f"s{si}b{bi}.down", [identity], h, w, 1, cin, width,
                    stride, None, ("stages", si, bi, "down"))
                add_deps.append(d)
            else:
                add_deps.append(identity)
            cost = L.elem_cost(h2 * w2 * width)
            meta = dict(cost.pop("meta"))
            meta.update(act="relu")
            add = g.add(f"s{si}b{bi}.add", OpKind.ADD, deps=add_deps,
                        fused_act="relu", meta=meta, **cost)
            prev, h, w, cin = add.node_id, h2, w2, width

    cost = L.elem_cost(cin)
    cost.pop("meta")
    gap = g.add("gap", OpKind.GLOBAL_POOL, deps=[prev], meta={}, **cost)
    fc_cost = L.dense_cost(cin, cfg["num_classes"])
    meta = dict(fc_cost.pop("meta"))
    meta.update(param=("fc",))
    g.add("fc", OpKind.MVM, deps=[gap.node_id], meta=meta, **fc_cost)
    g.validate()
    return g


def resnet8_graph() -> Graph:
    return build_resnet_graph(RESNET8)


def resnet18_graph() -> Graph:
    return build_resnet_graph(RESNET18_CIFAR)


#: Table I (paper): the 21 MVM/conv node ids of ResNet18-CIFAR.
TABLE1_IMC_NODE_IDS = frozenset(
    {1, 2, 3, 5, 6, 8, 9, 10, 12, 13, 15, 16, 17, 19, 20, 22, 23, 24, 26, 27, 30}
)


# ===========================================================================
# YOLOv8n — ONNX-granularity deployment graph (paper §V.C: 233 nodes,
# 63 convolutional, 57 followed by SiLU).
#
# At ONNX level a "Conv" ultralytics module is Conv + Sigmoid + Mul (SiLU
# is NOT fused in the exported graph the paper deploys — that is what
# makes the count 233); the DFL expectation is a fixed-weight 1x1 conv,
# modelled as an MVM node (the paper counts 63 *convolutional* nodes,
# excluding it).  The three detection scales are the paper's "3 parallel
# main branches".
# ===========================================================================



class _Emit:
    """Stateful helper emitting ONNX-level nodes with cost metadata."""

    def __init__(self, g: Graph):
        self.g = g

    def conv_module(self, name, dep, h, w, k, cin, cout, stride=1):
        """Conv + Sigmoid + Mul (SiLU) -> returns (mul_id, ho, wo)."""
        cid, ho, wo = _add_conv(self.g, f"{name}.conv", [dep] if dep else [],
                                h, w, k, cin, cout, stride, None,
                                param=(name,))
        n_el = ho * wo * cout
        sig = self._elem(f"{name}.sigmoid", OpKind.ACT, [cid], n_el)
        mul = self._elem(f"{name}.mul", OpKind.MUL, [cid, sig], n_el)
        return mul, ho, wo

    def plain_conv(self, name, dep, h, w, k, cin, cout, stride=1):
        cid, ho, wo = _add_conv(self.g, name, [dep], h, w, k, cin, cout,
                                stride, None, param=(name,))
        return cid, ho, wo

    def _elem(self, name, kind, deps, n_elems):
        cost = L.elem_cost(n_elems)
        cost.pop("meta")
        return self.g.add(name, kind, deps=deps, meta={}, **cost).node_id

    def elem(self, name, kind, deps, n_elems):
        return self._elem(name, kind, deps, n_elems)

    def c2f(self, name, dep, h, w, cin, cout, n, shortcut):
        c = cout // 2
        cv1, h, w = self.conv_module(f"{name}.cv1", dep, h, w, 1, cin, cout)
        split = self._elem(f"{name}.split", OpKind.SPLIT, [cv1], h * w * cout)
        chunks = [split, split]
        prev = split
        for i in range(n):
            m1, _, _ = self.conv_module(f"{name}.m{i}.cv1", prev, h, w, 3, c, c)
            m2, _, _ = self.conv_module(f"{name}.m{i}.cv2", m1, h, w, 3, c, c)
            if shortcut:
                prev = self._elem(f"{name}.m{i}.add", OpKind.ADD,
                                  [prev, m2], h * w * c)
            else:
                prev = m2
            chunks.append(prev)
        cat = self._elem(f"{name}.concat", OpKind.CONCAT, chunks,
                         h * w * (2 + n) * c)
        cv2, h, w = self.conv_module(f"{name}.cv2", cat, h, w, 1,
                                     (2 + n) * c, cout)
        return cv2, h, w

    def sppf(self, name, dep, h, w, c):
        cv1, h, w = self.conv_module(f"{name}.cv1", dep, h, w, 1, c, c // 2)
        n_el = h * w * (c // 2)
        p1 = self._elem(f"{name}.pool1", OpKind.POOL_MAX, [cv1], n_el)
        p2 = self._elem(f"{name}.pool2", OpKind.POOL_MAX, [p1], n_el)
        p3 = self._elem(f"{name}.pool3", OpKind.POOL_MAX, [p2], n_el)
        cat = self._elem(f"{name}.concat", OpKind.CONCAT, [cv1, p1, p2, p3],
                         h * w * 2 * c)
        cv2, h, w = self.conv_module(f"{name}.cv2", cat, h, w, 1, 2 * c, c)
        return cv2, h, w


def build_yolov8n_graph(cfg: dict = YOLOV8N) -> Graph:
    g = Graph(cfg["name"])
    e = _Emit(g)
    h, w = cfg["image_hw"]

    # ---- backbone -------------------------------------------------------
    b0, h, w = e.conv_module("b0", None, h, w, 3, 3, CH["p1"], 2)
    b1, h, w = e.conv_module("b1", b0, h, w, 3, CH["p1"], CH["p2"], 2)
    b2, h, w = e.c2f("b2", b1, h, w, CH["p2"], CH["p2"], 1, True)
    b3, h, w = e.conv_module("b3", b2, h, w, 3, CH["p2"], CH["p3"], 2)
    p3, h3, w3 = e.c2f("b4", b3, h, w, CH["p3"], CH["p3"], 2, True)
    b5, h, w = e.conv_module("b5", p3, h3, w3, 3, CH["p3"], CH["p4"], 2)
    p4, h4, w4 = e.c2f("b6", b5, h, w, CH["p4"], CH["p4"], 2, True)
    b7, h, w = e.conv_module("b7", p4, h4, w4, 3, CH["p4"], CH["p5"], 2)
    b8, h, w = e.c2f("b8", b7, h, w, CH["p5"], CH["p5"], 1, True)
    p5, h5, w5 = e.sppf("b9", b8, h, w, CH["p5"])

    # ---- neck (PAN) ------------------------------------------------------
    u1 = e.elem("n10.upsample", OpKind.UPSAMPLE, [p5], h4 * w4 * CH["p5"])
    c1 = e.elem("n11.concat", OpKind.CONCAT, [u1, p4],
                h4 * w4 * (CH["p4"] + CH["p5"]))
    n12, _, _ = e.c2f("n12", c1, h4, w4, CH["p4"] + CH["p5"], CH["p4"], 1, False)
    u2 = e.elem("n13.upsample", OpKind.UPSAMPLE, [n12], h3 * w3 * CH["p4"])
    c2 = e.elem("n14.concat", OpKind.CONCAT, [u2, p3],
                h3 * w3 * (CH["p3"] + CH["p4"]))
    n15, _, _ = e.c2f("n15", c2, h3, w3, CH["p3"] + CH["p4"], CH["p3"], 1, False)
    n16, _, _ = e.conv_module("n16", n15, h3, w3, 3, CH["p3"], CH["p3"], 2)
    c3 = e.elem("n17.concat", OpKind.CONCAT, [n16, n12],
                h4 * w4 * (CH["p3"] + CH["p4"]))
    n18, _, _ = e.c2f("n18", c3, h4, w4, CH["p3"] + CH["p4"], CH["p4"], 1, False)
    n19, _, _ = e.conv_module("n19", n18, h4, w4, 3, CH["p4"], CH["p4"], 2)
    c4 = e.elem("n20.concat", OpKind.CONCAT, [n19, p5],
                h5 * w5 * (CH["p4"] + CH["p5"]))
    n21, _, _ = e.c2f("n21", c4, h5, w5, CH["p4"] + CH["p5"], CH["p5"], 1, False)

    # ---- detect head: 3 scales, box (cv2) + cls (cv3) branches -----------
    feats = [(n15, h3, w3, CH["p3"]), (n18, h4, w4, CH["p4"]),
             (n21, h5, w5, CH["p5"])]
    c2_, c3_ = max(16, CH["p3"] // 4, 4 * REG_MAX), max(CH["p3"], min(NC, 100))
    scale_outs = []
    for i, (f, fh, fw, fc) in enumerate(feats):
        bx, _, _ = e.conv_module(f"head.cv2.{i}.0", f, fh, fw, 3, fc, c2_)
        bx, _, _ = e.conv_module(f"head.cv2.{i}.1", bx, fh, fw, 3, c2_, c2_)
        bx, _, _ = e.plain_conv(f"head.cv2.{i}.2", bx, fh, fw, 1, c2_,
                                4 * REG_MAX)
        cl, _, _ = e.conv_module(f"head.cv3.{i}.0", f, fh, fw, 3, fc, c3_)
        cl, _, _ = e.conv_module(f"head.cv3.{i}.1", cl, fh, fw, 3, c3_, c3_)
        cl, _, _ = e.plain_conv(f"head.cv3.{i}.2", cl, fh, fw, 1, c3_, NC)
        n_el = fh * fw * (4 * REG_MAX + NC)
        cat = e.elem(f"head.concat.{i}", OpKind.CONCAT, [bx, cl], n_el)
        rs = e.elem(f"head.reshape.{i}", OpKind.RESHAPE, [cat], n_el)
        scale_outs.append((rs, fh * fw))

    anchors = sum(a for _, a in scale_outs)          # 8400 at 640x640
    no = 4 * REG_MAX + NC
    zcat = e.elem("head.concat_scales", OpKind.CONCAT,
                  [nid for nid, _ in scale_outs], anchors * no)
    spl = e.elem("head.split_box_cls", OpKind.SPLIT, [zcat], anchors * no)

    # DFL: Reshape -> Transpose -> Softmax -> Conv(1x1 fixed) -> Reshape
    dfl_el = anchors * 4 * REG_MAX
    d1 = e.elem("dfl.reshape1", OpKind.RESHAPE, [spl], dfl_el)
    d2 = e.elem("dfl.transpose", OpKind.RESHAPE, [d1], dfl_el)
    d3 = e.elem("dfl.softmax", OpKind.SOFTMAX, [d2], dfl_el)
    dfl_cost = L.dense_cost(REG_MAX, 1)
    dfl_meta = dict(dfl_cost.pop("meta"))
    dfl_meta.update(param=None, n_vectors=anchors * 4)
    dfl_cost["flops"] = 2.0 * dfl_el
    dfl_cost["out_bytes"] = dfl_cost["out_elems"] = float(anchors * 4)
    d4 = g.add("dfl.conv", OpKind.MVM, deps=[d3], meta=dfl_meta,
               **dfl_cost).node_id
    d5 = e.elem("dfl.reshape2", OpKind.RESHAPE, [d4], anchors * 4)

    # dist2bbox: slices, subs/adds, concat, stride mul
    lt = e.elem("box.slice_lt", OpKind.SPLIT, [d5], anchors * 2)
    rb = e.elem("box.slice_rb", OpKind.SPLIT, [d5], anchors * 2)
    x1y1 = e.elem("box.sub_x1y1", OpKind.ADD, [lt], anchors * 2)
    x2y2 = e.elem("box.add_x2y2", OpKind.ADD, [rb], anchors * 2)
    csum = e.elem("box.add_center", OpKind.ADD, [x1y1, x2y2], anchors * 2)
    cdiv = e.elem("box.div_center", OpKind.MUL, [csum], anchors * 2)
    wh = e.elem("box.sub_wh", OpKind.ADD, [x1y1, x2y2], anchors * 2)
    bcat = e.elem("box.concat_xywh", OpKind.CONCAT, [cdiv, wh], anchors * 4)
    bmul = e.elem("box.mul_strides", OpKind.MUL, [bcat], anchors * 4)
    csig = e.elem("cls.sigmoid", OpKind.ACT, [spl], anchors * NC)
    e.elem("out.concat", OpKind.CONCAT, [bmul, csig], anchors * (4 + NC))

    g.validate()
    return g


def yolov8n_graph() -> Graph:
    return build_yolov8n_graph()
