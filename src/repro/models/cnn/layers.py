"""JAX building blocks for the paper's CNN workloads.

Pure-functional layers: every layer is ``init(key, ...) -> params`` plus
``apply(params, x, ...) -> y``.  Layouts are NHWC (TPU-native).  BatchNorm
is *folded* into the preceding conv at deployment time, matching the IMCE
software stack (the paper deploys quantized inference graphs where BN is
absorbed into weights/bias).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

try:
    import jax
    import jax.numpy as jnp
except ModuleNotFoundError:  # cost helpers stay importable without jax
    jax = jnp = None  # type: ignore[assignment]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _he_normal(key, shape, fan_in):
    return jax.random.normal(key, shape, dtype=jnp.float32) * math.sqrt(2.0 / fan_in)


def conv_init(key, k: int, cin: int, cout: int) -> Dict[str, jnp.ndarray]:
    """HWIO conv weights + bias (bias holds folded BN offsets)."""
    wkey, _ = jax.random.split(key)
    fan_in = k * k * cin
    return {
        "w": _he_normal(wkey, (k, k, cin, cout), fan_in),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def dense_init(key, cin: int, cout: int) -> Dict[str, jnp.ndarray]:
    wkey, _ = jax.random.split(key)
    return {
        "w": _he_normal(wkey, (cin, cout), cin),
        "b": jnp.zeros((cout,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# functional ops
# ---------------------------------------------------------------------------

def conv2d(params, x: jnp.ndarray, stride: int = 1, padding="SAME",
           act: Optional[str] = None) -> jnp.ndarray:
    y = jax.lax.conv_general_dilated(
        x, params["w"],
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = y + params["b"]
    return activate(y, act)


def dense(params, x: jnp.ndarray, act: Optional[str] = None) -> jnp.ndarray:
    y = x @ params["w"] + params["b"]
    return activate(y, act)


def activate(x: jnp.ndarray, act: Optional[str]) -> jnp.ndarray:
    if act is None:
        return x
    if act == "relu":
        return jax.nn.relu(x)
    if act == "silu":
        return jax.nn.silu(x)
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {act!r}")


def max_pool(x: jnp.ndarray, k: int, stride: Optional[int] = None,
             padding: str = "SAME") -> jnp.ndarray:
    stride = stride or k
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        window_dimensions=(1, k, k, 1),
        window_strides=(1, stride, stride, 1),
        padding=padding,
    )


def avg_pool(x: jnp.ndarray, k: int, stride: Optional[int] = None,
             padding: str = "VALID") -> jnp.ndarray:
    stride = stride or k
    summed = jax.lax.reduce_window(
        x, 0.0, jax.lax.add,
        window_dimensions=(1, k, k, 1),
        window_strides=(1, stride, stride, 1),
        padding=padding,
    )
    return summed / float(k * k)


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(1, 2))


def upsample_nearest(x: jnp.ndarray, factor: int = 2) -> jnp.ndarray:
    b, h, w, c = x.shape
    x = jnp.repeat(x, factor, axis=1)
    return jnp.repeat(x, factor, axis=2)


def softmax(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    return jax.nn.softmax(x, axis=axis)


# ---------------------------------------------------------------------------
# shape/cost bookkeeping shared with the deployment-graph builders
# ---------------------------------------------------------------------------

def conv_out_hw(h: int, w: int, k: int, stride: int, padding: str) -> Tuple[int, int]:
    if padding == "SAME":
        return (math.ceil(h / stride), math.ceil(w / stride))
    # VALID
    return ((h - k) // stride + 1, (w - k) // stride + 1)


def conv_cost(h: int, w: int, k: int, cin: int, cout: int, stride: int,
              padding: str = "SAME") -> dict:
    """FLOPs/bytes/IMC-metadata for one conv node (per single frame)."""
    ho, wo = conv_out_hw(h, w, k, stride, padding)
    macs = ho * wo * k * k * cin * cout
    params = k * k * cin * cout + cout
    return {
        "flops": 2.0 * macs,
        "weight_bytes": float(params),            # INT8 deployment: 1 B/param
        "out_bytes": float(ho * wo * cout),       # INT8 activations
        "out_elems": float(ho * wo * cout),
        "meta": {"cin_kk": k * k * cin, "cout": cout, "n_vectors": ho * wo,
                 "out_hw": (ho, wo)},
    }


def dense_cost(cin: int, cout: int) -> dict:
    return {
        "flops": 2.0 * cin * cout,
        "weight_bytes": float(cin * cout + cout),
        "out_bytes": float(cout),
        "out_elems": float(cout),
        "meta": {"cin_kk": cin, "cout": cout, "n_vectors": 1},
    }


def elem_cost(n_elems: float) -> dict:
    return {
        "flops": float(n_elems),
        "weight_bytes": 0.0,
        "out_bytes": float(n_elems),
        "out_elems": float(n_elems),
        "meta": {},
    }


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
