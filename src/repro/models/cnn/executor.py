"""Graph executor: run a deployment ``Graph`` as a real program.

The scheduler decides *where* nodes run (timing is emulated by the DES);
numerics are placement-invariant, so the executor walks the DAG in
topological order and evaluates each node with jnp ops, reading conv/fc
parameters from the model pytree via ``node.meta["param"]`` paths.

Two arithmetic modes:
* ``mode="float"`` — float32 reference.
* ``mode="int8"``  — per-node INT8 quantized execution (per-channel
  weights, per-tensor activations quantized at every node boundary),
  matching the paper's INT8 deployment.

Numerics parity with the un-scheduled reference model is asserted in
tests (float mode: exact; int8 mode: bounded quantization error).

Supported node kinds cover the ResNet graphs (the YOLO 233-node graph is
scheduled/simulated but executed at module level by ``yolo.forward``; see
DESIGN.md §3).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.graph import Graph, OpKind

from .. import quant
from . import layers as L


def _param_at(params, path):
    node = params
    for p in path:
        node = node[p]
    return node


def execute(g: Graph, params: Dict, x: jnp.ndarray, mode: str = "float",
            act_scales: Optional[Dict[str, float]] = None) -> jnp.ndarray:
    """Run graph ``g`` on batch ``x`` (NHWC).  Returns the sink output."""
    env: Dict[int, jnp.ndarray] = {}
    out = None
    for nid in g.topo_order():
        node = g.nodes[nid]
        preds = g.predecessors(nid)
        ins = [env[p] for p in preds]
        if node.kind == OpKind.CONV:
            inp = ins[0] if ins else x
            p = _param_at(params, node.meta["param"])
            if mode == "int8":
                s = (act_scales or {}).get(node.name)
                y = quant.quantized_conv2d(
                    inp, p["w"], p["b"], stride=node.meta["stride"],
                    padding=node.meta["padding"],
                    x_scale=None if s is None else jnp.float32(s))
                y = L.activate(y, node.meta.get("act"))
            else:
                y = L.conv2d(p, inp, stride=node.meta["stride"],
                             padding=node.meta["padding"],
                             act=node.meta.get("act"))
            env[nid] = y
        elif node.kind == OpKind.MVM:
            p = _param_at(params, node.meta["param"])
            if mode == "int8":
                y = quant.quantized_matmul(ins[0], p["w"], p["b"])
            else:
                y = L.dense(p, ins[0])
            env[nid] = y
        elif node.kind == OpKind.ADD:
            y = ins[0] + ins[1]
            env[nid] = L.activate(y, node.meta.get("act"))
        elif node.kind == OpKind.GLOBAL_POOL:
            env[nid] = L.global_avg_pool(ins[0])
        elif node.kind == OpKind.INPUT:
            env[nid] = x
        elif node.kind == OpKind.OUTPUT:
            env[nid] = ins[0]
        else:
            raise NotImplementedError(
                f"executor does not implement {node.kind} (node {node.name}); "
                "ResNet-family graphs only — see module docstring")
        out = env[nid]
    return out
