"""DAG intermediate representation for neural-network deployment graphs.

This is the paper's object of study: a CNN (or any DNN) is a directed
acyclic graph of *nodes* (fused operator groups, e.g. ``Conv+ReLU``) that
must be mapped onto a set of processing units.  The scheduler tier
(``repro.core.schedulers``) consumes this IR; the simulator
(``repro.core.simulator``) executes mappings over it.

Design notes
------------
* Node ids are 1-based integers to match the paper's Table I convention.
* ``OpKind`` distinguishes the functional class of every node; the *PU
  compatibility* of a node is derived from its kind (conv/MVM -> IMC,
  everything else -> DPU) exactly as described in §IV of the paper, but can
  be overridden per-node (``Node.pu_type``) for what-if studies.
* Longest path / levels / ancestor queries are pre-computed lazily and
  cached; all algorithms here are O(V+E) except ancestor bitsets which are
  O(V*E/64) — trivial for the paper's graphs (<= 233 nodes).
"""

from __future__ import annotations

import enum
import json
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


class PUType(enum.Enum):
    """Processing-unit class of the hybrid IMC device (paper §III)."""

    IMC = "imc"
    DPU = "dpu"


class OpKind(enum.Enum):
    """Functional class of a graph node.

    ``CONV``/``MVM`` are the in-memory-computable kinds; the rest are
    digital ops served by DPUs (paper §IV, first paragraph).
    Activations (ReLU/SiLU) are *fused* into their producer conv/MVM, as
    in the IMCE PUs ("optionally followed by activation functions").
    """

    CONV = "conv"
    MVM = "mvm"                 # fully-connected / matmul
    ADD = "add"
    MUL = "mul"
    POOL_MAX = "pool_max"
    POOL_AVG = "pool_avg"
    GLOBAL_POOL = "global_pool"
    CONCAT = "concat"
    SPLIT = "split"
    RESHAPE = "reshape"
    UPSAMPLE = "upsample"
    SOFTMAX = "softmax"
    ACT = "act"                 # standalone activation (not fused)
    INPUT = "input"
    OUTPUT = "output"
    # LM-tier kinds (used by core.pipeline_partition over transformer DAGs)
    ATTENTION = "attention"
    MOE = "moe"
    RECURRENT = "recurrent"
    EMBED = "embed"
    NORM = "norm"


#: op kinds that the IMC PUs execute natively (weight-stationary MVM class).
IMC_KINDS = frozenset(
    {OpKind.CONV, OpKind.MVM, OpKind.ATTENTION, OpKind.MOE, OpKind.EMBED}
)

#: zero-cost structural kinds (graph glue; the IMCE runtime folds these).
FREE_KINDS = frozenset({OpKind.INPUT, OpKind.OUTPUT})


def default_pu_type(kind: OpKind) -> PUType:
    """Paper §IV: conv/MVM -> IMC, every other function -> DPU."""
    return PUType.IMC if kind in IMC_KINDS else PUType.DPU


@dataclass
class Node:
    """One deployable node of the network graph.

    Attributes
    ----------
    node_id:   1-based unique id (paper Table I numbering).
    name:      human-readable name (e.g. ``layer2.0.conv1+relu``).
    kind:      functional class; determines PU compatibility.
    flops:     MAC-equivalent floating/fixed op count of the node.
    weight_bytes: stationary parameter footprint (INT8 bytes) — the IMC
               crossbar area the node occupies (paper Table I "Weights
               Area").  Zero for DPU ops.
    out_bytes: activation bytes forwarded to consumers (INT8).
    out_elems: number of output elements (drives DPU cost).
    pu_type:   which PU class executes this node (derived from kind unless
               overridden).
    fused_act: activation fused into this node ("relu"/"silu"/None).
    meta:      free-form dict (shapes, layer indices, ...).
    """

    node_id: int
    name: str
    kind: OpKind
    flops: float = 0.0
    weight_bytes: float = 0.0
    out_bytes: float = 0.0
    out_elems: float = 0.0
    pu_type: Optional[PUType] = None
    fused_act: Optional[str] = None
    meta: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.pu_type is None:
            self.pu_type = default_pu_type(self.kind)

    def is_free(self) -> bool:
        return self.kind in FREE_KINDS

    # -- replication (LRMP-style round-robin layer replicas) ---------------
    @property
    def replica_count(self) -> int:
        """Size of this node's replica group (1 = unreplicated)."""
        return int(self.meta.get("replica_count") or 1)

    @property
    def replica_index(self) -> Optional[int]:
        """This node's slot in its replica group (None = unreplicated).
        Replica ``i`` of a ``k``-group serves frames with ``f % k == i``."""
        return self.meta.get("replica_index")

    @property
    def replica_group(self) -> Optional[int]:
        """Base-graph node id of this node's replica group, if any."""
        return self.meta.get("replica_group")


class GraphError(ValueError):
    pass


class Graph:
    """A DNN deployment DAG.

    Edges carry the producer's activation bytes (compute-and-forward
    transfers go over shared DRAM / ICI between PUs).
    """

    def __init__(self, name: str = "graph") -> None:
        self.name = name
        self.nodes: Dict[int, Node] = {}
        self._succ: Dict[int, List[int]] = {}
        self._pred: Dict[int, List[int]] = {}
        self._topo_cache: Optional[List[int]] = None
        self._anc_cache: Optional[Dict[int, int]] = None  # id -> bitmask

    # -- construction ----------------------------------------------------
    def add_node(self, node: Node) -> Node:
        if node.node_id in self.nodes:
            raise GraphError(f"duplicate node id {node.node_id}")
        self.nodes[node.node_id] = node
        self._succ[node.node_id] = []
        self._pred[node.node_id] = []
        self._invalidate()
        return node

    def add(self, name: str, kind: OpKind, *, deps: Sequence[int] = (), **kw) -> Node:
        """Convenience: create node with the next free id and wire deps."""
        nid = (max(self.nodes) + 1) if self.nodes else 1
        node = Node(node_id=nid, name=name, kind=kind, **kw)
        self.add_node(node)
        for d in deps:
            self.add_edge(d, nid)
        return node

    def add_edge(self, src: int, dst: int) -> None:
        if src not in self.nodes or dst not in self.nodes:
            raise GraphError(f"edge ({src},{dst}) references unknown node")
        if dst not in self._succ[src]:
            self._succ[src].append(dst)
            self._pred[dst].append(src)
        self._invalidate()

    def _invalidate(self) -> None:
        self._topo_cache = None
        self._anc_cache = None
        # compiled simulation contexts (core.simcontext) are derived from
        # the structure; any mutation makes them stale.  The same goes for
        # the scratch cache (scheduler memos) and the replica-variant seed
        # link: both assume the structure they were derived from.
        self.__dict__.pop("_sim_contexts", None)
        self.__dict__.pop("_scratch", None)
        self.__dict__.pop("_ctx_seed", None)

    def scratch(self) -> dict:
        """Mutation-scoped scratch cache for derived deterministic figures
        (scheduler longest paths, lblp-r probe sessions, ...).  Cleared by
        ``_invalidate`` on any structural mutation; callers key entries by
        content (cost-model profile, fleet signature), never identity."""
        cache = self.__dict__.get("_scratch")
        if cache is None:
            cache = self.__dict__["_scratch"] = {}
        return cache

    # -- queries ----------------------------------------------------------
    def successors(self, nid: int) -> List[int]:
        return list(self._succ[nid])

    def predecessors(self, nid: int) -> List[int]:
        return list(self._pred[nid])

    def edges(self) -> Iterable[Tuple[int, int]]:
        for s, ds in self._succ.items():
            for d in ds:
                yield (s, d)

    def sources(self) -> List[int]:
        return [n for n in self.nodes if not self._pred[n]]

    def sinks(self) -> List[int]:
        return [n for n in self.nodes if not self._succ[n]]

    def __len__(self) -> int:
        return len(self.nodes)

    def num_nodes(self, kind: Optional[OpKind] = None,
                  pu_type: Optional[PUType] = None) -> int:
        out = 0
        for n in self.nodes.values():
            if kind is not None and n.kind != kind:
                continue
            if pu_type is not None and n.pu_type != pu_type:
                continue
            out += 1
        return out

    def total_weight_bytes(self) -> float:
        return sum(n.weight_bytes for n in self.nodes.values())

    # -- algorithms ---------------------------------------------------------
    def topo_order(self) -> List[int]:
        """Kahn topological order (stable: ready set kept sorted by id)."""
        if self._topo_cache is not None:
            return list(self._topo_cache)
        indeg = {n: len(self._pred[n]) for n in self.nodes}
        ready = sorted(n for n, d in indeg.items() if d == 0)
        order: List[int] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            inserted = False
            for s in self._succ[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
                    inserted = True
            if inserted:
                ready.sort()
        if len(order) != len(self.nodes):
            raise GraphError("graph has a cycle")
        self._topo_cache = order
        return list(order)

    def longest_path(self, time_of: Callable[[Node], float],
                     within: Optional[Iterable[int]] = None) -> List[int]:
        """Maximum-total-``time_of`` source->sink path (paper Alg. 1 step 1).

        Classic DAG dynamic program over the topological order.  Node
        weights only (edge transfer times are handled by the simulator,
        matching the paper which defines the LP over node execution
        times).  ``within`` restricts the DP to a node subset (per-tenant
        paths on a multi-tenant union); predecessors outside the subset
        are ignored.
        """
        members = None if within is None else set(within)
        best: Dict[int, float] = {}
        back: Dict[int, Optional[int]] = {}
        for nid in self.topo_order():
            if members is not None and nid not in members:
                continue
            t = time_of(self.nodes[nid])
            preds = [p for p in self._pred[nid] if p in best]
            if preds:
                p = max(preds, key=lambda q: best[q])
                best[nid] = best[p] + t
                back[nid] = p
            else:
                best[nid] = t
                back[nid] = None
        if not best:
            raise GraphError("longest_path over an empty node set")
        end = max(best, key=lambda q: best[q])
        path: List[int] = []
        cur: Optional[int] = end
        while cur is not None:
            path.append(cur)
            cur = back[cur]
        return path[::-1]

    def critical_time(self, time_of: Callable[[Node], float]) -> float:
        path = self.longest_path(time_of)
        return sum(time_of(self.nodes[n]) for n in path)

    # ancestor bitsets: parallel-branch tests --------------------------------
    def _ancestors(self) -> Dict[int, int]:
        if self._anc_cache is not None:
            return self._anc_cache
        idx = {nid: i for i, nid in enumerate(sorted(self.nodes))}
        anc: Dict[int, int] = {n: 0 for n in self.nodes}
        for nid in self.topo_order():
            m = 0
            for p in self._pred[nid]:
                m |= anc[p] | (1 << idx[p])
            anc[nid] = m
        self._anc_cache = anc
        self._anc_idx = idx
        return anc

    def is_parallel(self, a: int, b: int) -> bool:
        """True iff neither node is an ancestor of the other (parallel
        branches in the sense of the paper's branch constraint)."""
        if a == b:
            return False
        anc = self._ancestors()
        ia, ib = self._anc_idx[a], self._anc_idx[b]
        return not (anc[b] >> ia) & 1 and not (anc[a] >> ib) & 1

    # -- replication (paper-adjacent: LRMP, arXiv:2312.03146) ----------------
    def copy(self) -> "Graph":
        """Structural copy: fresh ``Node`` objects with independent meta
        dicts, same ids and edges.  Subclasses extend via :meth:`_copy_into`."""
        g = type(self)(self.name)
        self._copy_into(g)
        g._set_ctx_seed(self)
        return g

    def _set_ctx_seed(self, parent: "Graph") -> None:
        """Record the pristine ancestor this graph was derived from by a
        replica-preserving transform (copy / replicate / drop_replica).

        ``core.simcontext`` uses the link to seed a derived graph's
        compiled context from the ancestor's (bottom levels and cost
        tables are provably unchanged under those transforms).  The link
        is dropped by ``_invalidate`` the moment the derived graph is
        mutated further, because any other mutation voids that proof."""
        self.__dict__["_ctx_seed"] = parent.__dict__.get("_ctx_seed", parent)

    def ctx_seed(self) -> Optional["Graph"]:
        return self.__dict__.get("_ctx_seed")

    def _copy_into(self, g: "Graph") -> None:
        # direct dict construction: same nodes, same edge order as the
        # historical add_node/add_edge sequence, without the per-call
        # validation and invalidation (lblp-r derives dozens of variants)
        nodes, succ, pred = g.nodes, g._succ, g._pred
        for nid in sorted(self.nodes):
            n = self.nodes[nid]
            nodes[nid] = Node(
                node_id=n.node_id, name=n.name, kind=n.kind, flops=n.flops,
                weight_bytes=n.weight_bytes, out_bytes=n.out_bytes,
                out_elems=n.out_elems, pu_type=n.pu_type,
                fused_act=n.fused_act, meta=dict(n.meta),
            )
            succ[nid] = list(self._succ[nid])
            pred[nid] = list(self._pred[nid])
        g._invalidate()

    def replicate(self, node_id: int, k: int) -> "Graph":
        """Return a copy where ``node_id`` is cloned into ``k`` round-robin
        replicas (LRMP-style layer replication for bottleneck stages).

        Replica ``i`` executes the frames with ``f % k == i``: the simulator
        splits the frame stream round-robin across the group and merges the
        results at the consumers.  Every replica carries the node's full
        weight footprint (weights are duplicated across crossbars) but only
        ``1/k`` of the per-frame compute, which is what
        ``CostModel.frame_time`` charges.
        """
        g = self.copy()
        g._replicate_in_place(node_id, k)
        g._set_ctx_seed(self)
        return g

    def _replicate_in_place(self, node_id: int, k: int) -> None:
        """The body of :meth:`replicate` minus the copy, so
        :meth:`with_replicas` can apply several replications over one
        copy instead of copying the whole graph per replicated node."""
        if k < 1:
            raise GraphError(f"replica count must be >= 1, got {k}")
        node = self.nodes[node_id]  # unknown id -> KeyError
        if node.is_free():
            raise GraphError(f"cannot replicate structural node {node_id}")
        if node.replica_index is not None:
            raise GraphError(
                f"node {node_id} is already replicated; apply counts to the "
                "base graph instead (Graph.with_replicas)")
        if k == 1:
            return
        node.meta.update(replica_group=node_id, replica_index=0,
                         replica_count=k)
        preds = self.predecessors(node_id)
        succs = self.successors(node_id)
        for i in range(1, k):
            rid = max(self.nodes) + 1
            self.add_node(Node(
                node_id=rid, name=f"{node.name}@r{i}", kind=node.kind,
                flops=node.flops, weight_bytes=node.weight_bytes,
                out_bytes=node.out_bytes, out_elems=node.out_elems,
                pu_type=node.pu_type, fused_act=node.fused_act,
                meta={**dict(node.meta), "replica_group": node_id,
                      "replica_index": i, "replica_count": k},
            ))
            for p in preds:
                self.add_edge(p, rid)
            for s in succs:
                self.add_edge(rid, s)
            self._on_replica_added(node_id, rid)

    def _on_replica_added(self, base_id: int, replica_id: int) -> None:
        """Bookkeeping hook for subclasses (tenant registries etc.)."""

    def with_replicas(self, counts: Dict[int, int]) -> "Graph":
        """Apply several replications at once: ``counts`` maps base node id
        to total replica count (entries of 1 are no-ops).  Always returns a
        copy, so callers can derive variants from one pristine graph."""
        g = self.copy()
        for nid in sorted(counts):
            if counts[nid] > 1:
                g._replicate_in_place(nid, counts[nid])
        g._set_ctx_seed(self)
        return g

    def replica_groups(self) -> Dict[int, List[int]]:
        """Base node id -> sorted member ids, replicated groups only."""
        groups: Dict[int, List[int]] = {}
        for nid, n in self.nodes.items():
            if n.replica_group is not None:
                groups.setdefault(n.replica_group, []).append(nid)
        return {b: sorted(ms) for b, ms in groups.items()}

    def drop_replica(self, node_id: int) -> "Graph":
        """Return a copy with replica ``node_id`` removed from its group.

        Survivors are re-indexed ``0..k-2`` (count ``k-1``); a group reduced
        to one member loses its replica tags entirely.  The elastic tier
        uses this to absorb a failed PU's replicated nodes without a full
        re-schedule.
        """
        node = self.nodes[node_id]
        if node.replica_group is None:
            raise GraphError(f"node {node_id} is not a replica")
        g = self.copy()
        members = [m for m in g.replica_groups()[node.replica_group]
                   if m != node_id]
        g._remove_node(node_id)
        members.sort(key=lambda m: g.nodes[m].meta["replica_index"])
        for i, m in enumerate(members):
            meta = g.nodes[m].meta
            if len(members) == 1:
                for key in ("replica_group", "replica_index", "replica_count"):
                    meta.pop(key, None)
            else:
                meta["replica_index"] = i
                meta["replica_count"] = len(members)
        g._set_ctx_seed(self)
        return g

    def _remove_node(self, nid: int) -> None:
        for p in self._pred[nid]:
            self._succ[p].remove(nid)
        for s in self._succ[nid]:
            self._pred[s].remove(nid)
        del self.nodes[nid], self._succ[nid], self._pred[nid]
        self._invalidate()

    def depth_levels(self) -> Dict[int, int]:
        """ASAP level of every node (hop count, used by RR tie-breaks)."""
        lvl: Dict[int, int] = {}
        for nid in self.topo_order():
            preds = self._pred[nid]
            lvl[nid] = 1 + max((lvl[p] for p in preds), default=-1)
        return lvl

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "name": self.name,
                "nodes": [
                    {
                        "id": n.node_id,
                        "name": n.name,
                        "kind": n.kind.value,
                        "flops": n.flops,
                        "weight_bytes": n.weight_bytes,
                        "out_bytes": n.out_bytes,
                        "out_elems": n.out_elems,
                        "pu_type": n.pu_type.value,
                        "fused_act": n.fused_act,
                        "meta": n.meta,
                    }
                    for n in self.nodes.values()
                ],
                "edges": list(self.edges()),
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "Graph":
        raw = json.loads(text)
        g = cls(raw["name"])
        for nd in raw["nodes"]:
            g.add_node(
                Node(
                    node_id=nd["id"],
                    name=nd["name"],
                    kind=OpKind(nd["kind"]),
                    flops=nd["flops"],
                    weight_bytes=nd["weight_bytes"],
                    out_bytes=nd["out_bytes"],
                    out_elems=nd["out_elems"],
                    pu_type=PUType(nd["pu_type"]),
                    fused_act=nd.get("fused_act"),
                    meta=nd.get("meta", {}),
                )
            )
        for s, d in raw["edges"]:
            g.add_edge(s, d)
        return g

    def validate(self) -> None:
        self.topo_order()  # raises on cycle
        for nid, node in self.nodes.items():
            if node.node_id != nid:
                raise GraphError(f"node key {nid} != node_id {node.node_id}")


class MultiTenantGraph(Graph):
    """Tagged disjoint union of per-model deployment graphs.

    Multi-tenant serving: several CNNs are resident on the same PU fleet at
    once, each receiving its own frame stream.  The union is itself a
    ``Graph`` — every scheduler and the simulator consume it unchanged —
    but nodes carry their tenant tag (``node.meta["tenant"]``) and the
    union remembers each tenant's node set, sources and sinks, so
    schedulers can balance *per-tenant* critical paths and the simulator
    can drive *per-tenant* frame streams.

    Node ids of ingested graphs are remapped onto disjoint ranges
    (``_id_map`` keeps tenant-local id -> union id); the constituent
    graphs are never mutated.

    Tenants additionally carry a *weight* (priority, default 1.0): the
    simulator's fair-queueing virtual time divides each tenant's
    per-frame resource charge by its weight, so a weight-2 tenant is
    entitled to twice the fleet share of a weight-1 tenant, and
    ``lblp-mt`` places higher-weight tenants' critical paths first.
    Weights are serving policy, not structure: changing one never
    invalidates compiled simulation contexts or scheduler caches (the
    consumers key their memos by weight content instead).
    """

    def __init__(self, name: str = "multi-tenant") -> None:
        super().__init__(name)
        self.tenants: List[str] = []
        self.tenant_weights: Dict[str, float] = {}
        self._tenant_nodes: Dict[str, List[int]] = {}
        self._id_map: Dict[str, Dict[int, int]] = {}

    # -- construction ----------------------------------------------------
    @classmethod
    def union(cls, graphs: Sequence[Graph],
              names: Optional[Sequence[str]] = None,
              name: str = "multi-tenant") -> "MultiTenantGraph":
        """Build the tagged disjoint union of ``graphs``.

        ``names`` defaults to the constituent graphs' names, deduplicated
        with ``#k`` suffixes so two instances of the same model can be
        co-resident.
        """
        mt = cls(name)
        if names is None:
            names = []
            seen: Dict[str, int] = {}
            for g in graphs:
                k = seen.get(g.name, 0)
                seen[g.name] = k + 1
                names.append(g.name if k == 0 else f"{g.name}#{k}")
        if len(names) != len(graphs):
            raise GraphError("names/graphs length mismatch")
        for g, tenant in zip(graphs, names):
            mt.add_tenant(g, tenant)
        return mt

    def add_tenant(self, g: Graph, tenant: Optional[str] = None) -> str:
        """Ingest one model graph under tag ``tenant`` (default: its name)."""
        tenant = tenant if tenant is not None else g.name
        if tenant in self._tenant_nodes:
            raise GraphError(f"duplicate tenant '{tenant}'")
        if not g.nodes:
            raise GraphError(f"tenant '{tenant}' has an empty graph")
        base = max(self.nodes) if self.nodes else 0
        remap: Dict[int, int] = {}
        for old_id in sorted(g.nodes):
            n = g.nodes[old_id]
            new_id = base + len(remap) + 1
            remap[old_id] = new_id
            self.add_node(Node(
                node_id=new_id,
                name=f"{tenant}/{n.name}",
                kind=n.kind,
                flops=n.flops,
                weight_bytes=n.weight_bytes,
                out_bytes=n.out_bytes,
                out_elems=n.out_elems,
                pu_type=n.pu_type,
                fused_act=n.fused_act,
                meta={**n.meta, "tenant": tenant},
            ))
        for s, d in g.edges():
            self.add_edge(remap[s], remap[d])
        self.tenants.append(tenant)
        self._tenant_nodes[tenant] = sorted(remap.values())
        self._id_map[tenant] = remap
        return tenant

    def remove_tenant(self, tenant: str) -> None:
        """Remove one tenant's component (including any replicas of its
        nodes) from the union in place.  Structural mutation: compiled
        simulation contexts and scratch caches are invalidated exactly
        like any other graph edit."""
        if tenant not in self._tenant_nodes:
            raise GraphError(f"unknown tenant '{tenant}'")
        for nid in list(self._tenant_nodes[tenant]):
            self._remove_node(nid)
        self.tenants.remove(tenant)
        del self._tenant_nodes[tenant]
        del self._id_map[tenant]
        self.tenant_weights.pop(tenant, None)

    # -- tenant weights (serving priority) ---------------------------------
    def set_tenant_weight(self, tenant: str, weight: float) -> None:
        """Set a tenant's serving weight (relative fleet-share priority).

        Intentionally does *not* invalidate compiled contexts: weights
        are not graph structure.  Consumers (the simulator's run memo,
        ``measured_rate``) key their caches by weight content."""
        if tenant not in self._tenant_nodes:
            raise GraphError(f"unknown tenant '{tenant}'")
        if not weight > 0:
            raise GraphError(f"tenant weight must be > 0, got {weight}")
        if weight == 1.0:
            self.tenant_weights.pop(tenant, None)
        else:
            self.tenant_weights[tenant] = float(weight)

    def tenant_weight(self, tenant: str) -> float:
        return self.tenant_weights.get(tenant, 1.0)

    # -- replication bookkeeping -------------------------------------------
    def copy(self) -> "MultiTenantGraph":
        mt: MultiTenantGraph = super().copy()  # type: ignore[assignment]
        mt.tenants = list(self.tenants)
        mt.tenant_weights = dict(self.tenant_weights)
        mt._tenant_nodes = {t: list(ns) for t, ns in self._tenant_nodes.items()}
        mt._id_map = {t: dict(m) for t, m in self._id_map.items()}
        return mt

    def _on_replica_added(self, base_id: int, replica_id: int) -> None:
        tenant = self.nodes[base_id].meta.get("tenant")
        if tenant is not None:
            # replica ids are allocated past max(nodes): append keeps order
            self._tenant_nodes[tenant].append(replica_id)

    def _remove_node(self, nid: int) -> None:
        tenant = self.nodes[nid].meta.get("tenant")
        super()._remove_node(nid)
        if tenant is not None and tenant in self._tenant_nodes:
            self._tenant_nodes[tenant] = [
                n for n in self._tenant_nodes[tenant] if n != nid]
            self._id_map[tenant] = {
                k: v for k, v in self._id_map[tenant].items() if v != nid}

    # -- per-tenant queries ------------------------------------------------
    def tenant_of(self, nid: int) -> str:
        node = self.nodes[nid]  # unknown id -> KeyError, not a tag error
        try:
            return node.meta["tenant"]
        except KeyError:
            raise GraphError(f"node {nid} has no tenant tag") from None

    def tenant_nodes(self, tenant: str) -> List[int]:
        return list(self._tenant_nodes[tenant])

    def tenant_sources(self, tenant: str) -> List[int]:
        return [n for n in self._tenant_nodes[tenant] if not self._pred[n]]

    def tenant_sinks(self, tenant: str) -> List[int]:
        return [n for n in self._tenant_nodes[tenant] if not self._succ[n]]

    def union_id(self, tenant: str, local_id: int) -> int:
        """Union node id of ``local_id`` in the tenant's original graph."""
        return self._id_map[tenant][local_id]

    def tenant_longest_path(self, tenant: str,
                            time_of: Callable[[Node], float]) -> List[int]:
        """Longest path restricted to one tenant's component.

        Components are disjoint, so the DP over the union's topological
        order filtered to the tenant's nodes is exact.
        """
        return self.longest_path(time_of, within=self._tenant_nodes[tenant])

    # -- (de)serialization: tenant structure must survive the round-trip ----
    def to_json(self) -> str:
        # node meta (tenant tags, replica tags, cost hints) is already
        # serialized by the base class
        raw = json.loads(super().to_json())
        raw["tenants"] = list(self.tenants)
        raw["id_map"] = self._id_map
        if self.tenant_weights:
            raw["tenant_weights"] = dict(self.tenant_weights)
        return json.dumps(raw, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "MultiTenantGraph":
        raw = json.loads(text)
        mt = cls(raw["name"])
        for nd in raw["nodes"]:
            mt.add_node(
                Node(
                    node_id=nd["id"],
                    name=nd["name"],
                    kind=OpKind(nd["kind"]),
                    flops=nd["flops"],
                    weight_bytes=nd["weight_bytes"],
                    out_bytes=nd["out_bytes"],
                    out_elems=nd["out_elems"],
                    pu_type=PUType(nd["pu_type"]),
                    fused_act=nd.get("fused_act"),
                    meta=nd.get("meta", {}),
                )
            )
        for s, d in raw["edges"]:
            mt.add_edge(s, d)
        mt.tenants = list(raw["tenants"])
        mt.tenant_weights = dict(raw.get("tenant_weights", {}))
        mt._id_map = {t: {int(k): v for k, v in m.items()}
                      for t, m in raw["id_map"].items()}
        # rebuild from the node tags, not _id_map: replicas added after
        # union-time are tenant members without a tenant-local id
        mt._tenant_nodes = {
            t: sorted(nid for nid, n in mt.nodes.items()
                      if n.meta.get("tenant") == t)
            for t in mt.tenants
        }
        return mt
