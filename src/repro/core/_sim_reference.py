"""The pre-compilation event loop, preserved as a reference engine.

This is the historical dict-keyed ``_run_streams`` implementation that
``simulator.py`` replaced with the compiled ``SimContext`` loop.  It is
kept (verbatim, minus the module it lived in) for two jobs:

* **equivalence oracle** — ``tests/test_sim_property.py`` drives random
  DAGs x assignments x replica configs through both loops and asserts
  bit-identical outputs, a far stronger net than the fixed goldens;
* **honest speedup measurement** — ``benchmarks/sim_speed.py`` times
  this loop against the compiled one on the real workloads and records
  the ratio in ``BENCH_sim.json``.

Do not "fix" or optimize this module: its value is being frozen.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

from .simulator import IMCESimulator, MultiTenantSimulator


class _ReferenceLoopMixin:
    """Overrides ``_run_streams`` with the historical implementation."""

    def _run_streams(
        self, a, frames, in_flight: int,
        rates: Optional[Dict[str, float]] = None,
        light: bool = False,  # signature compat; the oracle always
        # materializes everything (the loop below is the frozen original)
    ) -> Tuple[float, Dict[str, List[float]],
               Dict[int, List[Tuple[float, float]]],
               Dict[str, List[float]], Dict[str, Dict[int, float]]]:
        g, cm = self.g, self.cm
        view = self._stream_view(a)
        if isinstance(frames, int):
            frames = {s: frames for s in view.streams}
        order = g.topo_order()
        preds = {n: g.predecessors(n) for n in order}
        succs = {n: g.successors(n) for n in order}
        streams = view.streams

        pu_of = dict(a.mapping)
        for nid in order:
            if nid not in pu_of:
                nbr = succs[nid] + preds[nid]
                pu_of[nid] = next(
                    (pu_of[m] for m in nbr if m in pu_of), a.pus[0].pu_id
                )
        speed = {p.pu_id: p for p in a.pus}

        rep_cnt = {n: g.nodes[n].replica_count for n in order}
        rep_idx = {n: g.nodes[n].meta.get("replica_index", 0) for n in order}
        replicated = any(c > 1 for c in rep_cnt.values())

        def active(nid: int, f: int) -> bool:
            c = rep_cnt[nid]
            return c == 1 or f % c == rep_idx[nid]

        def exec_time(nid: int) -> float:
            node = g.nodes[nid]
            if node.is_free():
                return 0.0
            pu = speed[pu_of[nid]]
            return cm.time(node, pu.pu_type, pu.speed)

        evq: List[Tuple[float, int, str, tuple]] = []
        seq = 0

        def push(t: float, kind: str, payload: tuple) -> None:
            nonlocal seq
            heapq.heappush(evq, (t, seq, kind, payload))
            seq += 1

        missing: Dict[Tuple[str, int, int], int] = {}
        inject_time: Dict[Tuple[str, int], float] = {}
        complete_time: Dict[Tuple[str, int], float] = {}
        frame_left: Dict[Tuple[str, int], int] = {}
        injected = {s: 0 for s in streams}
        n_sinks = {s: len(view.sinks[s]) for s in streams}
        ready_q: Dict[int, List[Tuple[float, int, float, int, float]]] = {
            p.pu_id: [] for p in a.pus
        }
        pu_free_at: Dict[int, float] = {p.pu_id: 0.0 for p in a.pus}
        pu_idle: Dict[int, bool] = {p.pu_id: True for p in a.pus}
        busy_iv: Dict[int, List[Tuple[float, float]]] = {p.pu_id: [] for p in a.pus}
        stream_busy: Dict[str, Dict[int, float]] = {
            s: {p.pu_id: 0.0 for p in a.pus} for s in streams
        }
        completions: Dict[str, List[float]] = {s: [] for s in streams}

        def inject(sn: str, f: int, t: float) -> None:
            inject_time[(sn, f)] = t
            if not replicated:
                frame_left[(sn, f)] = n_sinks[sn]
                for nid in view.nodes[sn]:
                    missing[(sn, f, nid)] = len(preds[nid])
                for nid in view.sources[sn]:
                    push(t, "ready", (sn, f, nid))
            else:
                sinks = 0
                for nid in view.nodes[sn]:
                    if not active(nid, f):
                        continue
                    missing[(sn, f, nid)] = sum(
                        1 for p in preds[nid] if active(p, f))
                    if not any(active(s, f) for s in succs[nid]):
                        sinks += 1
                    if missing[(sn, f, nid)] == 0:
                        push(t, "ready", (sn, f, nid))
                frame_left[(sn, f)] = sinks
            injected[sn] += 1

        def enqueue_ready(sn: str, f: int, nid: int, t: float) -> None:
            pid = pu_of[nid]
            heapq.heappush(
                ready_q[pid],
                (f * view.weight[sn], f, -self._blevel[nid], nid, t))
            if pu_idle[pid]:
                push(max(t, pu_free_at[pid]), "dispatch", (pid,))

        def finish(sn: str, f: int, nid: int, t: float) -> None:
            node = g.nodes[nid]
            outs = succs[nid]
            if replicated:
                outs = [s for s in outs if active(s, f)]
            if not outs:
                frame_left[(sn, f)] -= 1
                if frame_left[(sn, f)] == 0:
                    completions[sn].append(t)
                    complete_time[(sn, f)] = t
                    push(t, "complete", (sn, f))
                return
            for s in outs:
                xfer = cm.transfer(node, same_pu=(pu_of[s] == pu_of[nid]))
                push(t + xfer, "arrive", (sn, f, s))

        if rates is not None:
            for sn in streams:
                r = rates[sn]
                if r <= 0:
                    raise ValueError(f"rate for stream '{sn}' must be > 0")
                for f in range(frames[sn]):
                    push(f / r, "inject", (sn, f))
        else:
            for sn in streams:
                for f in range(min(in_flight, frames[sn])):
                    inject(sn, f, 0.0)

        makespan = 0.0
        while evq:
            t, _, kind, payload = heapq.heappop(evq)
            makespan = max(makespan, t)
            if kind == "inject":
                sn, f = payload
                inject(sn, f, t)
            elif kind == "ready":
                sn, f, nid = payload
                enqueue_ready(sn, f, nid, t)
            elif kind == "arrive":
                sn, f, nid = payload
                missing[(sn, f, nid)] -= 1
                if missing[(sn, f, nid)] == 0:
                    push(t, "ready", (sn, f, nid))
            elif kind == "dispatch":
                (pid,) = payload
                if not pu_idle[pid] or not ready_q[pid]:
                    continue
                _vt, f, _negbl, nid, _tr = heapq.heappop(ready_q[pid])
                sn = view.stream_of[nid]
                dt = exec_time(nid)
                pu_idle[pid] = False
                start = max(t, pu_free_at[pid])
                end = start + dt
                pu_free_at[pid] = end
                if dt > 0:
                    busy_iv[pid].append((start, end))
                    stream_busy[sn][pid] += dt
                push(end, "done", (pid, sn, f, nid))
            elif kind == "done":
                pid, sn, f, nid = payload
                pu_idle[pid] = True
                finish(sn, f, nid, t)
                if ready_q[pid]:
                    push(t, "dispatch", (pid,))
            elif kind == "complete":
                sn, f = payload
                if rates is None and injected[sn] < frames[sn]:
                    inject(sn, injected[sn], t)
        sojourns = {
            sn: [complete_time[(sn, f)] - inject_time[(sn, f)]
                 for f in range(frames[sn]) if (sn, f) in complete_time]
            for sn in streams
        }
        self.last_events = seq
        return (makespan, {s: sorted(c) for s, c in completions.items()},
                busy_iv, sojourns, stream_busy)


class ReferenceSimulator(_ReferenceLoopMixin, IMCESimulator):
    """Single-model simulator running the historical event loop."""


class ReferenceMultiTenantSimulator(_ReferenceLoopMixin, MultiTenantSimulator):
    """Multi-tenant simulator running the historical event loop."""
