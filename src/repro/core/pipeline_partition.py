"""LBLP as a transformer pipeline-stage partitioner (the paper's
technique as a first-class LM-tier feature; DESIGN.md §2).

A transformer is lowered to a deployment Graph whose nodes are layer
blocks (attention / MoE / SSM / recurrent / embed / head), with FLOPs-
derived costs per node.  The stage fleet is modelled as homogeneous
"IMC" PUs (every stage runs every block kind on TPU), and LBLP's
load-balance-longest-path policy assigns blocks to stages.  For dense
stacks this reduces to balanced contiguous chunking; for MoE / hybrid
stacks the heterogeneous per-block costs make the balance non-trivial —
exactly the regime the paper targets.

Contiguity: pipeline stages must hold *contiguous* layer ranges (a
transformer layer chain is sequential).  LBLP's mapping is therefore
projected to the nearest contiguous partition preserving per-stage load
ordering — the classic "chain partitioning" projection; the quality gap
vs unrestricted LBLP is reported so the effect is visible.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.configs.base import LMConfig

from .cost import CostModel, PUSpec
from .graph import Graph, OpKind, PUType
from .schedulers import get_scheduler


def transformer_block_graph(cfg: LMConfig, seq_len: int) -> Graph:
    """Layer-block DAG with per-block FLOPs (forward, per token batch of 1
    sequence of ``seq_len``)."""
    g = Graph(f"{cfg.name}-blocks")
    d, s = cfg.d_model, seq_len
    embed = g.add("embed", OpKind.EMBED, flops=2.0 * s * d,
                  weight_bytes=cfg.vocab * d, out_bytes=s * d,
                  out_elems=s * d)
    prev = embed.node_id

    def attn_flops() -> float:
        hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
        proj = 2.0 * s * d * (H * hd + 2 * KV * hd + H * hd)
        qk_av = 2.0 * 2.0 * s * s * H * hd
        return proj + qk_av

    def ffn_flops() -> float:
        if cfg.n_experts:
            return 2.0 * 3 * s * cfg.top_k * d * cfg.d_ff
        mats = 2 if cfg.mlp_kind == "plain" else 3
        return 2.0 * mats * s * d * cfg.d_ff

    def rec_flops() -> float:
        di = cfg.d_inner or d
        return 2.0 * s * (2 * d * di + 2 * di * di) + 10.0 * s * di

    def ssm_flops() -> float:
        di = cfg.d_inner or 2 * d
        n = cfg.ssm_state or 16
        return 2.0 * s * (2 * d * di + di * cfg.dt_rank * 2
                          + di * 2 * n) + 12.0 * s * di * n

    li = 0
    for seg in cfg.segments:
        kinds: List[str]
        if seg.kind == "hybrid3":
            kinds = ["rec", "rec", "attn"] * seg.n
        else:
            kinds = [seg.kind] * seg.n
        for kind in kinds:
            if kind in ("attn", "xattn"):
                fl = attn_flops() + ffn_flops()
                wb = 4 * d * cfg.hd * cfg.n_heads + (
                    cfg.n_experts * 3 * d * cfg.d_ff if cfg.n_experts
                    else 3 * d * cfg.d_ff)
                op = OpKind.MOE if cfg.n_experts else OpKind.ATTENTION
            elif kind == "ssm":
                fl, wb, op = ssm_flops(), 3 * d * (cfg.d_inner or d), \
                    OpKind.RECURRENT
            else:  # rec
                fl = rec_flops() + ffn_flops()
                wb = 4 * d * (cfg.d_inner or d) + 3 * d * cfg.d_ff
                op = OpKind.RECURRENT
            node = g.add(f"L{li}.{kind}", op, deps=[prev], flops=fl,
                         weight_bytes=float(wb), out_bytes=float(s * d),
                         out_elems=float(s * d),
                         meta={"layer": li, "kind": kind})
            prev = node.node_id
            li += 1
    g.add("head", OpKind.MVM, deps=[prev], flops=2.0 * s * d * cfg.vocab,
          weight_bytes=float(d * cfg.vocab), out_bytes=float(s * cfg.vocab),
          out_elems=float(s * cfg.vocab),
          meta={"cin_kk": d, "cout": cfg.vocab, "n_vectors": s})
    g.validate()
    return g


@dataclass
class StagePlan:
    stage_of: Dict[int, int]            # node_id -> stage
    boundaries: List[int]               # layer indices starting each stage
    loads: List[float]                  # per-stage flops
    imbalance: float                    # max/mean load
    lblp_bottleneck: float              # unrestricted-LBLP bound (reference)


def _flops_cost_model() -> CostModel:
    """Homogeneous TPU stages: time ~ flops (197 TFLOP/s bf16)."""

    class FlopsCM(CostModel):
        def _time_uncached(self, node, pu_type):
            return node.flops / 197e12

    return FlopsCM()


def partition(cfg: LMConfig, n_stages: int, seq_len: int = 4096
              ) -> StagePlan:
    g = transformer_block_graph(cfg, seq_len)
    cm = _flops_cost_model()
    # homogeneous stage fleet: model every stage as an IMC-class PU with
    # infinite weight capacity (HBM modeled separately)
    pus = [PUSpec(pu_id=i + 1, pu_type=PUType.IMC, weight_capacity=float("inf"))
           for i in range(n_stages)]
    for n in g.nodes.values():
        n.pu_type = PUType.IMC           # every block runs on a TPU stage
    a = get_scheduler("lblp", cm).schedule(g, pus)
    lblp_bneck = a.bottleneck(g, cm)

    # ---- contiguity projection (chain partitioning) ---------------------
    order = g.topo_order()
    costs = [cm.time(g.nodes[n]) for n in order]
    total = sum(costs)
    target = total / n_stages
    boundaries = [0]
    acc = 0.0
    stage_of: Dict[int, int] = {}
    stage = 0
    loads = [0.0] * n_stages
    for i, (nid, c) in enumerate(zip(order, costs)):
        if acc + c / 2.0 > target * (stage + 1) and stage < n_stages - 1:
            stage += 1
            boundaries.append(i)
        stage_of[nid] = stage
        loads[stage] += c
        acc += c
    mean = total / n_stages
    return StagePlan(
        stage_of=stage_of,
        boundaries=boundaries,
        loads=loads,
        imbalance=max(loads) / mean if mean else 1.0,
        lblp_bottleneck=lblp_bneck,
    )
