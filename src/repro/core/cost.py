"""Execution-time cost models for the hybrid IMC/DPU platform.

The paper schedules on *measured* per-node execution times from the FPGA
IMCE.  Those measurements are not public, so we model them analytically
from the node's tensor shapes and the emulated hardware's documented
behaviour, and expose the model behind the same interface a measurement
table would use (``CostModel.time(node, pu_spec)``).  The paper's claims
are about *relative* behaviour (orderings, ratios, convergence), which an
analytic model reproduces; EXPERIMENTS.md §Paper-validation checks those
claims, not absolute milliseconds.

IMC PU model (weight-stationary crossbar, paper §III / NeuroSoC)
----------------------------------------------------------------
A conv/MVM node of weight shape (Cout, Cin*K*K) is tiled onto R x C
crossbars: ``tiles = ceil(Cin*K*K / R) * ceil(Cout / C)``.  Every output
position issues one analog MVM per row-tile; column tiles run in
parallel across the crossbars *within* the PU up to ``xbars_per_pu``;
beyond that they serialize.  Fused ReLU/SiLU is free (in the PU's
datapath).

    t_imc(node) = n_vectors * serial_tiles * t_mvm + t_setup
    n_vectors   = H_out * W_out          (1 for an MVM/linear node)
    serial_tiles= ceil(row_tiles * col_tiles / xbars_per_pu)

DPU model (digital elementwise/pool/move engine)
------------------------------------------------
    t_dpu(node) = out_elems / elem_rate + t_setup
conv/MVM *can* run on a DPU at ``dpu_mac_rate`` MAC/s (paper: "functions
similar to IMC-PUs are also supported but with lower performance").

Transfers (compute-and-forward over shared DRAM / IPI)
------------------------------------------------------
    t_xfer(bytes) = bytes / dram_bw + t_ipi      (0 if same PU)

All constants live in a named ``HardwareProfile`` so experiments can swap
calibrations; ``IMCE_DEFAULT`` approximates the NeuroSoC-class emulator
(INT8, 512x512 crossbars).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional

from .graph import Graph, Node, OpKind, PUType


@dataclass(frozen=True)
class HardwareProfile:
    """Calibration constants for one emulated hardware generation."""

    name: str = "imce-default"
    # IMC side
    xbar_rows: int = 512
    xbar_cols: int = 512
    xbars_per_pu: int = 4
    t_mvm: float = 250e-9          # s per crossbar MVM issue
    imc_setup: float = 2e-6        # s fixed per node invocation
    #: stationary-weight capacity of one IMC PU (INT8 bytes).  Calibrated so
    #: ResNet18-CIFAR (2.8M params) on 8 IMC PUs reproduces Table I's
    #: "weights area" scale (several PUs near 100%).
    pu_weight_capacity: float = 700e3
    # DPU side
    dpu_elem_rate: float = 2.0e9   # elementwise ops / s
    dpu_mac_rate: float = 0.5e9    # MAC/s when conv/MVM falls back to DPU
    dpu_setup: float = 2e-6
    # interconnect (shared DRAM + inter-processor interrupts)
    dram_bw: float = 8.0e9         # bytes/s effective
    t_ipi: float = 3e-6            # s per forwarded tensor hand-off


IMCE_DEFAULT = HardwareProfile()

#: A faster-interconnect profile used in sensitivity studies.
IMCE_FAST_LINK = replace(IMCE_DEFAULT, name="imce-fast-link", dram_bw=32e9, t_ipi=1e-6)


@dataclass(frozen=True)
class PUSpec:
    """One physical processing unit instance."""

    pu_id: int
    pu_type: PUType
    #: relative speed factor (1.0 = profile nominal); lets experiments model
    #: heterogeneous-capacity fleets and degraded/straggler units.
    speed: float = 1.0
    weight_capacity: Optional[float] = None  # bytes; None -> profile default

    def capacity(self, prof: HardwareProfile) -> float:
        if self.weight_capacity is not None:
            return self.weight_capacity
        return prof.pu_weight_capacity if self.pu_type is PUType.IMC else math.inf


def make_pus(n_imc: int, n_dpu: int, profile: HardwareProfile = IMCE_DEFAULT,
             ) -> List[PUSpec]:
    """Standard fleet: ``n_imc`` IMC PUs then ``n_dpu`` DPU PUs, ids 1-based."""
    pus = [PUSpec(pu_id=i + 1, pu_type=PUType.IMC) for i in range(n_imc)]
    pus += [PUSpec(pu_id=n_imc + i + 1, pu_type=PUType.DPU) for i in range(n_dpu)]
    return pus


class CostModel:
    """Analytic per-node execution/transfer times on a hardware profile."""

    def __init__(self, profile: HardwareProfile = IMCE_DEFAULT) -> None:
        self.profile = profile
        self._cache: Dict[tuple, float] = {}

    # -- node execution ----------------------------------------------------
    def time(self, node: Node, pu_type: Optional[PUType] = None,
             speed: float = 1.0) -> float:
        """Execution time of ``node`` on a PU of ``pu_type`` (default: the
        node's preferred type)."""
        pu_type = pu_type or node.pu_type
        # Fast path: a per-node side table (attached to the node object,
        # so it can never alias across nodes) keyed by the profile
        # *object* plus the call args.  Node cost inputs are set at
        # construction time, so the entry stays valid for the node's
        # lifetime; a different profile simply misses into the slow path.
        tc = node.__dict__.get("_time_cache")
        if tc is not None and tc[0] is self.profile:
            t = tc[1].get((pu_type, speed))
            if t is not None:
                return t
        # Memoize on the cost-relevant content, never on object identity:
        # an id()-based key aliases when a dead node's address is reused by
        # a new graph, handing back a stale time (a CostModel routinely
        # outlives the graphs it prices, e.g. across benchmark sweeps).
        meta = node.meta
        key = (node.kind, pu_type, speed, node.flops, node.out_elems,
               meta.get("cin_kk"), meta.get("cout"), meta.get("n_vectors"))
        t = self._cache.get(key)
        if t is None:
            t = self._time_uncached(node, pu_type) / max(speed, 1e-12)
            self._cache[key] = t
        if tc is None or tc[0] is not self.profile:
            tc = (self.profile, {})
            node.__dict__["_time_cache"] = tc
        tc[1][(pu_type, speed)] = t
        return t

    def _time_uncached(self, node: Node, pu_type: PUType) -> float:
        p = self.profile
        if node.is_free():
            return 0.0
        if node.kind in (OpKind.CONV, OpKind.MVM):
            if pu_type is PUType.IMC:
                return self._imc_time(node)
            # digital fallback
            return node.flops / p.dpu_mac_rate + p.dpu_setup
        # digital ops; IMC PUs cannot run them at all.
        if pu_type is PUType.IMC:
            return math.inf
        return node.out_elems / p.dpu_elem_rate + p.dpu_setup

    def frame_time(self, node: Node, pu_type: Optional[PUType] = None,
                   speed: float = 1.0) -> float:
        """Per-frame amortized execution time (LRMP accounting).

        A node replicated ``k``-way serves every k-th frame round-robin, so
        each replica contributes ``time/k`` to its PU's steady-state
        per-frame load; the max-per-PU sum of these is the pipeline
        interval bound.  Identical to :meth:`time` for unreplicated nodes.
        """
        return self.time(node, pu_type, speed) / node.replica_count

    def _imc_time(self, node: Node) -> float:
        p = self.profile
        meta = node.meta
        cin_kk = meta.get("cin_kk")
        cout = meta.get("cout")
        n_vectors = meta.get("n_vectors")
        if cin_kk is None or cout is None or n_vectors is None:
            # Fallback purely from flops: flops = n_vectors * cin_kk * cout.
            # Assume a square-ish MVM the size of one crossbar.
            n_vectors = max(1.0, node.flops / (p.xbar_rows * p.xbar_cols))
            cin_kk, cout = p.xbar_rows, p.xbar_cols
        row_tiles = math.ceil(cin_kk / p.xbar_rows)
        col_tiles = math.ceil(cout / p.xbar_cols)
        serial = math.ceil(row_tiles * col_tiles / p.xbars_per_pu)
        return n_vectors * serial * p.t_mvm + p.imc_setup

    # -- transfers -----------------------------------------------------------
    def transfer(self, src: Node, same_pu: bool) -> float:
        if same_pu or src.out_bytes == 0:
            return 0.0
        p = self.profile
        return src.out_bytes / p.dram_bw + p.t_ipi

    # -- aggregates ------------------------------------------------------------
    def graph_times(self, g: Graph) -> Dict[int, float]:
        return {nid: self.time(n) for nid, n in g.nodes.items()}

    def longest_path(self, g: Graph) -> List[int]:
        return g.longest_path(lambda n: self.time(n))

    def table(self, g: Graph) -> str:
        """Debug: per-node cost table."""
        rows = ["id  name                          kind      pu    time_us  weightKB"]
        for nid in g.topo_order():
            n = g.nodes[nid]
            rows.append(
                f"{nid:<3d} {n.name:<28s} {n.kind.value:<9s} {n.pu_type.value:<5s}"
                f" {self.time(n)*1e6:8.1f} {n.weight_bytes/1e3:8.1f}"
            )
        return "\n".join(rows)
