"""Random (RD) — paper §IV.

"Initially, a number of nodes equal to the available PUs are randomly
selected and assigned to different PUs to ensure full utilization of
resources.  The remaining nodes are then assigned randomly to a PU."

Type compatibility is respected: the seeding phase draws, per PU, a
random not-yet-assigned node executable on it; the fill phase assigns
each remaining node to a uniformly random compatible PU (retrying on
capacity overflow, then waiving).
"""

from __future__ import annotations

import random
from typing import Dict, Sequence

from ..cost import PUSpec
from ..graph import Graph
from .base import Assignment, Scheduler, schedulable_nodes


class RDScheduler(Scheduler):
    name = "rd"

    def __init__(self, cost_model=None, seed: int = 0) -> None:
        super().__init__(cost_model)
        self.seed = seed

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        rng = random.Random(self.seed)
        mapping: Dict[int, int] = {}
        weights: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        spills = []

        remaining = {n.node_id: n for n in schedulable_nodes(g)}

        # Phase 1: seed every PU with one random compatible node.
        for p in rng.sample(list(pus), len(pus)):
            cands = [
                n for n in remaining.values()
                if p in self._compatible(n, pus) and self._fits(n, p, weights)
            ]
            if not cands:
                continue
            node = rng.choice(sorted(cands, key=lambda n: n.node_id))
            mapping[node.node_id] = p.pu_id
            weights[p.pu_id] += node.weight_bytes
            del remaining[node.node_id]

        # Phase 2: everything else goes to a random compatible PU.
        for nid in sorted(remaining):
            node = remaining[nid]
            cands = self._compatible(node, pus)
            pool = [p for p in cands if self._fits(node, p, weights)]
            if not pool:
                pool = cands
                spills.append(nid)
            p = rng.choice(pool)
            mapping[nid] = p.pu_id
            weights[p.pu_id] += node.weight_bytes

        return Assignment(mapping=mapping, pus=list(pus), algorithm=self.name,
                          meta={"seed": self.seed, "capacity_spills": spills})
