"""Weights-Balance (WB) — the paper's Algorithm 2.

Step 1: IMC nodes sorted descending by *weights size*; each goes to the
IMC PU with the smallest assigned weights size.
Step 2: DPU nodes sorted descending by *execution time*; each goes to the
DPU PU with the smallest total execution time.

WB balances crossbar area, not time — the paper shows this concentrates
the compute-heavy early conv layers (big activations, small kernels) onto
few PUs, collapsing utilization (Table I: 24.4% mean vs LBLP's 78.3%).
"""

from __future__ import annotations

from typing import Dict, Sequence

from ..cost import PUSpec
from ..graph import Graph, PUType
from .base import Assignment, Scheduler, schedulable_nodes


class WBScheduler(Scheduler):
    name = "wb"

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        cm = self.cm
        mapping: Dict[int, int] = {}
        load: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        weights: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        spills = []

        nodes = schedulable_nodes(g)

        # Step 1: IMC nodes by descending weight size -> min-weights PU.
        imc_nodes = sorted(
            (n for n in nodes if n.pu_type == PUType.IMC),
            key=lambda n: (-n.weight_bytes, n.node_id),
        )
        for node in imc_nodes:
            cands = self._compatible(node, pus)
            pool = [p for p in cands if self._fits(node, p, weights)]
            if not pool:
                pool = cands
                spills.append(node.node_id)
            best = min(pool, key=lambda p: (weights[p.pu_id], p.pu_id))
            mapping[node.node_id] = best.pu_id
            weights[best.pu_id] += node.weight_bytes
            # replicas charge amortized steady-state load (time == frame_time
            # on unreplicated graphs)
            load[best.pu_id] += cm.frame_time(node, best.pu_type, best.speed)

        # Step 2: DPU nodes by descending execution time -> min-load PU.
        dpu_nodes = sorted(
            (n for n in nodes if n.pu_type == PUType.DPU),
            key=lambda n: (-cm.time(n), n.node_id),
        )
        for node in dpu_nodes:
            cands = self._compatible(node, pus)
            best = min(cands, key=lambda p: (load[p.pu_id], p.pu_id))
            mapping[node.node_id] = best.pu_id
            # replicas charge amortized steady-state load (time == frame_time
            # on unreplicated graphs)
            load[best.pu_id] += cm.frame_time(node, best.pu_type, best.speed)

        return Assignment(mapping=mapping, pus=list(pus), algorithm=self.name,
                          meta={"capacity_spills": spills})
