"""LBLP-X — beyond-paper improved variant of LBLP.

Three additions over the paper's Algorithm 1:

1. **Criticality tie-break.**  When several PUs share the minimum load,
   prefer the PU whose last-assigned node is *not* a graph neighbour of
   the candidate (reduces serialization of dependent chains on one PU).
2. **Communication-aware placement.**  The greedy key becomes
   ``load + lambda * cross_edge_time`` where ``cross_edge_time`` is the
   added DRAM/IPI transfer cost the placement would introduce on edges to
   already-placed neighbours.  ``lambda`` defaults to 1 (transfer seconds
   weigh like compute seconds on the pipeline's critical path).
3. **Local-search refinement.**  After the greedy pass, first-improvement
   swap/move search over node pairs, accepting changes that reduce the
   vector (bottleneck_load, simulated_latency) lexicographically; budget
   bounded.

On the paper's CNNs this closes most of the gap between LBLP and the
branch-and-bound optimum (see benchmarks/scheduler_quality.py).
"""

from __future__ import annotations

from typing import Dict, Sequence

from ..cost import PUSpec
from ..graph import Graph, Node, PUType
from .base import Assignment, Scheduler, schedulable_nodes
from .lblp import LBLPScheduler


class LBLPXScheduler(Scheduler):
    name = "lblp-x"

    def __init__(self, cost_model=None, comm_lambda: float = 1.0,
                 refine_budget: int = 4000) -> None:
        super().__init__(cost_model)
        self.comm_lambda = comm_lambda
        self.refine_budget = refine_budget

    # -- phase 1: comm-aware greedy (LBLP ordering) ------------------------
    def _greedy(self, g: Graph, pus: Sequence[PUSpec]) -> Dict[int, int]:
        cm = self.cm
        mapping: Dict[int, int] = {}
        load: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        weights: Dict[int, float] = {p.pu_id: 0.0 for p in pus}

        lp = g.longest_path(lambda n: cm.time(n))
        lp_set = set(lp)

        def comm_penalty(node: Node, pid: int) -> float:
            t = 0.0
            for q in g.predecessors(node.node_id):
                if q in mapping and mapping[q] != pid:
                    t += cm.transfer(g.nodes[q], same_pu=False)
            for s in g.successors(node.node_id):
                if s in mapping and mapping[s] != pid:
                    t += cm.transfer(node, same_pu=False)
            return t

        def has_parallel(node: Node, pid: int) -> bool:
            return any(g.is_parallel(node.node_id, o)
                       for o, q in mapping.items() if q == pid)

        def assign(node: Node) -> None:
            cands = self._compatible(node, pus)
            pool = [p for p in cands if self._fits(node, p, weights)] or cands
            # Unlike paper-LBLP's hard branch filter, branch separation is
            # only a tie-break here: load balance is never sacrificed.
            best = min(
                pool,
                key=lambda p: (
                    load[p.pu_id] + self.comm_lambda * comm_penalty(node, p.pu_id),
                    has_parallel(node, p.pu_id),
                    p.pu_id,
                ),
            )
            mapping[node.node_id] = best.pu_id
            # replicas charge amortized steady-state load (time == frame_time
            # on unreplicated graphs)
            load[best.pu_id] += cm.frame_time(node, best.pu_type, best.speed)
            weights[best.pu_id] += node.weight_bytes

        nodes = schedulable_nodes(g)
        for group in (
            [n for n in nodes if n.node_id in lp_set],
            [n for n in nodes if n.node_id not in lp_set],
        ):
            for pu_type in (PUType.IMC, PUType.DPU):
                batch = [n for n in group if n.pu_type == pu_type]
                batch.sort(key=lambda n: (-cm.time(n), n.node_id))
                for node in batch:
                    assign(node)
        return mapping

    # -- phase 2: local search -------------------------------------------------
    def _objective(self, g: Graph, pus: Sequence[PUSpec],
                   mapping: Dict[int, int]) -> tuple:
        from ..simulator import IMCESimulator  # local import: avoid cycle

        a = Assignment(mapping=mapping, pus=list(pus), algorithm="tmp")
        bneck = a.bottleneck(g, self.cm)
        lat = IMCESimulator(g, self.cm).latency_only(a)
        return (bneck, lat)

    def _refine(self, g: Graph, pus: Sequence[PUSpec],
                mapping: Dict[int, int]) -> Dict[int, int]:
        cm = self.cm
        best = dict(mapping)
        best_obj = self._objective(g, pus, best)
        budget = self.refine_budget
        nodes = [n for n in schedulable_nodes(g)]
        pu_by_id = {p.pu_id: p for p in pus}
        improved = True
        while improved and budget > 0:
            improved = False
            # moves
            for n in nodes:
                for p in self._compatible(n, pus):
                    if best[n.node_id] == p.pu_id:
                        continue
                    cand = dict(best)
                    cand[n.node_id] = p.pu_id
                    if not self._cap_ok(g, pus, cand):
                        continue
                    budget -= 1
                    obj = self._objective(g, pus, cand)
                    if obj < best_obj:
                        best, best_obj, improved = cand, obj, True
                        break
                    if budget <= 0:
                        break
                if improved or budget <= 0:
                    break
            if improved or budget <= 0:
                continue
            # swaps
            for i, a in enumerate(nodes):
                for b in nodes[i + 1:]:
                    if a.pu_type != b.pu_type or best[a.node_id] == best[b.node_id]:
                        continue
                    cand = dict(best)
                    cand[a.node_id], cand[b.node_id] = cand[b.node_id], cand[a.node_id]
                    if not self._cap_ok(g, pus, cand):
                        continue
                    budget -= 1
                    obj = self._objective(g, pus, cand)
                    if obj < best_obj:
                        best, best_obj, improved = cand, obj, True
                        break
                    if budget <= 0:
                        break
                if improved or budget <= 0:
                    break
        return best

    def _cap_ok(self, g: Graph, pus: Sequence[PUSpec],
                mapping: Dict[int, int]) -> bool:
        used: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        caps = {p.pu_id: p.capacity(self.cm.profile) for p in pus}
        for nid, pid in mapping.items():
            used[pid] += g.nodes[nid].weight_bytes
            if used[pid] > caps[pid] * (1 + 1e-9):
                return False
        return True

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        mapping = self._greedy(g, pus)
        if not self._cap_ok(g, pus, mapping):
            # fall back to plain LBLP (its waiver bookkeeping) when the
            # comm-aware greedy overpacks a PU
            mapping = LBLPScheduler(self.cm).schedule(g, pus).mapping
        refined = self._refine(g, pus, mapping)
        return Assignment(mapping=refined, pus=list(pus), algorithm=self.name)
