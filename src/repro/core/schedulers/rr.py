"""Round-Robin (RR) — paper §IV.

"The algorithm first performs a topological sort on the network to
establish a valid execution order and then sorts nodes in ascending order
based on their unique node IDs.  The nodes are then assigned sequentially
to PUs in a round-robin fashion."

PU-type compatibility still applies (a pooling node cannot run on an IMC
PU), so the rotation is maintained *per PU type*, cycling through the
compatible sub-fleet — the natural reading of the paper's description on
a hybrid fleet.  Capacity overflows fall through to the next PU in the
cycle.
"""

from __future__ import annotations

from typing import Dict, Sequence

from ..cost import PUSpec
from ..graph import Graph, PUType
from .base import Assignment, Scheduler


class RRScheduler(Scheduler):
    name = "rr"

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        mapping: Dict[int, int] = {}
        weights: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        cursor: Dict[PUType, int] = {PUType.IMC: 0, PUType.DPU: 0}
        spills = []

        order = sorted(g.topo_order())  # topo sort, then ascending node id
        for nid in order:
            node = g.nodes[nid]
            if node.is_free():
                continue
            cands = self._compatible(node, pus)
            k = cursor[node.pu_type] % len(cands)
            # advance past full PUs if any PU still fits the node
            chosen = None
            for off in range(len(cands)):
                p = cands[(k + off) % len(cands)]
                if self._fits(node, p, weights):
                    chosen = p
                    cursor[node.pu_type] = (k + off + 1)
                    break
            if chosen is None:
                chosen = cands[k]
                cursor[node.pu_type] = k + 1
                spills.append(nid)
            mapping[nid] = chosen.pu_id
            weights[chosen.pu_id] += node.weight_bytes

        return Assignment(mapping=mapping, pus=list(pus), algorithm=self.name,
                          meta={"capacity_spills": spills})
