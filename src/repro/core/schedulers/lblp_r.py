"""LBLP-R — LBLP with LRMP-style bottleneck layer replication.

LRMP (arXiv:2312.03146) shows that on spatial IMC accelerators the
single biggest throughput lever is *replicating* bottleneck layers
across spare crossbars: the pipeline interval is bounded by the
most-loaded PU, and once load balancing has done its work the residual
bottleneck is one heavy layer that no placement can split — unless it is
cloned and the frame stream divided round-robin across the clones
(``Graph.replicate``).

Greedy loop (budgeted, gain-gated):

  1. Schedule the so-far-replicated graph with LBLP (Algorithm 1) — or
     LBLP-MT on a multi-tenant union — and read the per-PU amortized
     frame loads.
  2. Walk the bottleneck PU's nodes, heaviest amortized frame-time first,
     and clone the first one whose replica group can still grow (group
     size < compatible PU count) one step wider.
  3. Keep the replica iff the re-scheduled *sorted load vector* improves
     lexicographically — comparing vectors, not just the max, lets the
     loop work through tied bottlenecks (several equally-loaded PUs must
     all be relieved before the max moves, the common CNN case).  Stop
     when no candidate improves (the balance gain has flattened) or the
     replica budget is exhausted.
  4. If the final analytic bound did not beat the unreplicated bound by
     at least ``min_gain`` (relative), revert to the plain LBLP result —
     lblp-r therefore never returns a schedule with a worse bound.
  5. Optionally (``validate_rate=<frames>``) measure both candidates in
     the discrete-event simulator and keep the replicated schedule only
     if its processing rate is at least the baseline's.  The analytic
     bound ignores finite in-flight budgets (Little's law: added
     cross-PU transfers lengthen sojourns and can eat a small bound
     gain under bounded buffering), so deployments that care about the
     measured figure can demand it.

Because transfers are DMA (they never occupy a PU), a lower bound
translates directly into a higher saturated processing rate; replication
costs only duplicated crossbar weights, which the capacity constraint
already polices.

The returned assignment maps node ids of the *replicated* graph:
``meta["replicated_graph"]`` carries that graph, ``meta["replicas"]``
the base-node replica counts.  ``schedule_replicated`` is the
convenience wrapper returning ``(replicated_graph, assignment)``.

Incremental probes
------------------
One scheduling pass evaluates dozens of replica variants, and a budget
sweep (the replication benchmark) re-evaluates every budget's prefix
from scratch.  All candidate evaluation therefore runs through a
*probe session* cached on the base graph (``Graph.scratch``), keyed by
(cost model, fleet, inner scheduler): each distinct replica-count
signature is derived, scheduled and load-vectored exactly once, and —
because the session hands back one shared graph object per signature —
the derived graph's compiled ``SimContext`` (seeded from the base
graph's, see ``core.simcontext``) and its content-keyed
``measured_rate`` memo survive across ``validate_rate`` probes, budget
sweeps and benchmark rows alike.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

from ..cost import CostModel, PUSpec
from ..graph import Graph, MultiTenantGraph, PUType
from .base import Assignment, ScheduleError, Scheduler
from .lblp import LBLPScheduler
from .lblp_mt import LBLPMTScheduler

from ..simcontext import MEMO_CAP as _MEMO_CAP  # shared ctx.memo bound


class _ProbeSession:
    """Replica-variant probe cache for one (base graph, cm, fleet,
    inner scheduler) combination; see module docstring."""

    def __init__(self, g: Graph, cm: CostModel, pus: Sequence[PUSpec],
                 inner: Scheduler) -> None:
        self.g = g
        self.cm = cm
        self.pus = list(pus)
        self.inner = inner
        self._variants: Dict[tuple, dict] = {}

    @staticmethod
    def signature(counts: Dict[int, int]) -> tuple:
        return tuple(sorted((k, v) for k, v in counts.items() if v > 1))

    def probe(self, counts: Dict[int, int]) -> dict:
        """Derived graph + inner schedule + load figures for ``counts``,
        computed once per signature and shared thereafter."""
        key = self.signature(counts)
        e = self._variants.get(key)
        if e is None:
            g_v = self.g.with_replicas(dict(counts)) if key else self.g
            a = self.inner.schedule(g_v, self.pus)
            load = a.load(g_v, self.cm)
            # sorted descending: lexicographic "smaller" == better balance
            vec = tuple(sorted(load.values(), reverse=True))
            e = self._variants[key] = {
                "graph": g_v, "assignment": a, "load": load, "vec": vec,
            }
        return e

    @staticmethod
    def for_graph(g: Graph, cm: CostModel, pus: Sequence[PUSpec],
                  inner: Scheduler) -> "_ProbeSession":
        key = ("lblp-r-probe", type(cm), cm.profile, inner.name,
               getattr(inner, "branch_constraint", None),
               tuple((p.pu_id, p.pu_type, p.speed, p.weight_capacity)
                     for p in pus))
        sess = g.scratch().get(key)
        if sess is None:
            sess = g.scratch()[key] = _ProbeSession(g, cm, pus, inner)
        return sess


class LBLPRScheduler(Scheduler):
    name = "lblp-r"

    def __init__(self, cost_model=None, branch_constraint: bool = True,
                 replica_budget: Optional[int] = None,
                 min_gain: float = 0.02,
                 validate_rate: Optional[int] = None,
                 sim_engine: str = "exact") -> None:
        super().__init__(cost_model)
        self.branch_constraint = branch_constraint
        #: max number of extra replicas to add; None -> fleet size
        self.replica_budget = replica_budget
        #: minimum relative bound improvement to accept the replication
        self.min_gain = min_gain
        #: simulate both candidates for this many frames and revert if the
        #: replicated schedule's measured rate regresses (None = bound only)
        self.validate_rate = validate_rate
        #: simulation engine for the validation probes ("exact" default;
        #: benchmarks pass "periodic" — both candidates are always
        #: measured with the same engine, so the accept/revert decision
        #: is self-consistent)
        self.sim_engine = sim_engine

    def _inner(self, g: Graph) -> Scheduler:
        if isinstance(g, MultiTenantGraph) and len(g.tenants) > 1:
            return LBLPMTScheduler(self.cm, self.branch_constraint)
        return LBLPScheduler(self.cm, self.branch_constraint)

    @staticmethod
    def _bound(a: Assignment, g: Graph, cm: CostModel) -> float:
        load = a.load(g, cm)
        return max(load.values()) if load else 0.0

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        if g.replica_groups():
            raise ScheduleError(
                "lblp-r wants the unreplicated base graph; it derives "
                "replica counts itself (meta['replicas'])")
        cm = self.cm
        inner = self._inner(g)
        budget = (self.replica_budget if self.replica_budget is not None
                  else len(pus))
        n_by_type = {pt: sum(1 for p in pus if p.pu_type is pt)
                     for pt in PUType}

        sess = _ProbeSession.for_graph(g, cm, pus, inner)
        counts: Dict[int, int] = {}
        base_e = sess.probe(counts)
        base_a = base_e["assignment"]
        base_bound = max(base_e["load"].values()) if base_e["load"] else 0.0
        best_g: Graph = g
        best_a = base_a
        best_vec = base_e["vec"]
        best_load = base_e["load"]

        extra = 0
        while extra < budget:
            load = best_load
            bottleneck_pu = max(load, key=lambda p: (load[p], -p))
            cands = [best_g.nodes[nid]
                     for nid, pid in best_a.mapping.items()
                     if pid == bottleneck_pu and not best_g.nodes[nid].is_free()]
            cands.sort(key=lambda n: (-cm.frame_time(n), n.node_id))
            improved = False
            for node in cands:
                base = (node.node_id if node.replica_group is None
                        else node.replica_group)
                k_new = counts.get(base, 1) + 1
                # wider than the compatible sub-fleet is pure weight waste
                if k_new > max(n_by_type.get(g.nodes[base].pu_type, 0), 1):
                    continue
                try_counts = {**counts, base: k_new}
                e = sess.probe(try_counts)
                if e["vec"] < best_vec:
                    counts = try_counts
                    best_g, best_a = e["graph"], e["assignment"]
                    best_vec, best_load = e["vec"], e["load"]
                    improved = True
                    break
            if not improved:
                break
            extra += 1

        best_bound = best_vec[0] if best_vec else 0.0
        if not best_bound < base_bound * (1 - self.min_gain):
            # gain never materialized: replication is not free (duplicated
            # weights, extra transfers) — fall back to plain LBLP
            counts, best_g, best_a, extra = {}, g, base_a, 0
            best_bound = base_bound
        elif self.validate_rate and counts:
            if measured_rate(best_g, best_a, cm, self.validate_rate,
                             engine=self.sim_engine) \
                    < measured_rate(g, base_a, cm, self.validate_rate,
                                    engine=self.sim_engine):
                counts, best_g, best_a, extra = {}, g, base_a, 0
                best_bound = base_bound

        return Assignment(
            mapping=dict(best_a.mapping),
            pus=list(pus),
            algorithm=self.name,
            meta={**best_a.meta,
                  "base_algorithm": inner.name,
                  "replicas": dict(counts),
                  "extra_replicas": extra,
                  "replicated_graph": best_g,
                  "bound_interval": best_bound},
        )


def measured_rate(g: Graph, a: Assignment, cm: Optional[CostModel],
                  frames: int, sim=None, engine: str = "exact") -> float:
    """Simulated saturated processing rate of ``a`` over ``g`` (aggregate
    tenant rate on multi-tenant unions) — the validation metric lblp-r
    and the replication benchmark share.

    Runs only the saturated-throughput pass (the latency and isolated
    passes of ``run()`` cost ~2x more simulator work and do not affect
    the rate); the values are identical to ``SimResult.rate`` /
    ``sum(tenants[*].rate)`` from a full ``run()`` at the same frames.

    Callers probing the same graph repeatedly can pass a prebuilt
    ``sim`` to share one engine; otherwise one is built here — cheap
    either way, because the compiled ``SimContext`` (topo order, bottom
    levels, adjacency) is cached on the graph object and the
    per-assignment ``ExecPlan`` on the context, so repeated probes stop
    re-deriving graph structure.  ``engine`` selects the simulation
    engine for freshly built simulators (see
    :func:`repro.core.make_simulator`).
    """
    # imported here: simulator -> schedulers.base is the layering; this
    # validation hook is the one place the arrow points back
    from .. import make_simulator
    if sim is None:
        sim = make_simulator(g, cm, engine=engine)
    # the rate is a deterministic function of (mapping, fleet, frames,
    # engine) over this context's graph: memoize by content, because the
    # lblp-r budget sweep re-derives identical candidate schedules as
    # fresh objects (the id-keyed ExecPlan cache cannot see that)
    memo = getattr(sim, "_ctx", None) and sim._ctx.memo
    key = None
    if memo is not None:
        key = ("measured_rate", type(sim).__name__, sim.mode, frames,
               tuple(sorted(a.mapping.items())),
               tuple((p.pu_id, p.pu_type, p.speed) for p in a.pus))
        hit = memo.get(key)
        if hit is not None:
            # LRU touch: re-insert so the entry survives eviction while
            # a scheduling pass keeps probing it
            del memo[key]
            memo[key] = hit
            return hit
    if isinstance(g, MultiTenantGraph) and len(g.tenants) > 1:
        _, completions, _, _, _ = sim._run_streams(
            a, {t: frames for t in g.tenants},
            in_flight=len(a.pus) + 2, light=True)
        total = 0.0
        for comps in completions.values():
            interval, _ = sim._steady_state(comps)
            total += 1.0 / interval if interval > 0 else math.inf
    else:
        _, completions, _, _, _ = sim._run_streams(
            a, frames=frames, in_flight=len(a.pus) + 2, light=True)
        interval, _ = sim._steady_state(completions[next(iter(completions))])
        total = 1.0 / interval if interval > 0 else math.inf
    if key is not None:
        while len(memo) >= _MEMO_CAP:
            # bounded LRU: evict the stalest entry, never the whole
            # cache (a mid-search wipe used to throw away every probe
            # of the current scheduling pass)
            memo.pop(next(iter(memo)))
        memo[key] = total
    return total


def schedule_replicated(g: Graph, pus: Sequence[PUSpec],
                        cost_model: Optional[CostModel] = None,
                        **kw) -> Tuple[Graph, Assignment]:
    """Run lblp-r and return ``(replicated_graph, assignment)`` — the pair
    the simulator needs (the mapping refers to the replicated graph)."""
    a = LBLPRScheduler(cost_model, **kw).schedule(g, pus)
    return a.meta["replicated_graph"], a
