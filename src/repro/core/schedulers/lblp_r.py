"""LBLP-R — LBLP with LRMP-style bottleneck layer replication.

LRMP (arXiv:2312.03146) shows that on spatial IMC accelerators the
single biggest throughput lever is *replicating* bottleneck layers
across spare crossbars: the pipeline interval is bounded by the
most-loaded PU, and once load balancing has done its work the residual
bottleneck is one heavy layer that no placement can split — unless it is
cloned and the frame stream divided round-robin across the clones
(``Graph.replicate``).

Greedy loop (budgeted, gain-gated):

  1. Schedule the so-far-replicated graph with LBLP (Algorithm 1) — or
     LBLP-MT on a multi-tenant union — and read the per-PU amortized
     frame loads.
  2. Walk the bottleneck PU's nodes, heaviest amortized frame-time first,
     and clone the first one whose replica group can still grow (group
     size < compatible PU count) one step wider.
  3. Keep the replica iff the re-scheduled *sorted load vector* improves
     lexicographically — comparing vectors, not just the max, lets the
     loop work through tied bottlenecks (several equally-loaded PUs must
     all be relieved before the max moves, the common CNN case).  Stop
     when no candidate improves (the balance gain has flattened) or the
     replica budget is exhausted.
  4. If the final analytic bound did not beat the unreplicated bound by
     at least ``min_gain`` (relative), revert to the plain LBLP result —
     lblp-r therefore never returns a schedule with a worse bound.
  5. Optionally (``validate_rate=<frames>``) measure both candidates in
     the discrete-event simulator and keep the replicated schedule only
     if its processing rate is at least the baseline's.  The analytic
     bound ignores finite in-flight budgets (Little's law: added
     cross-PU transfers lengthen sojourns and can eat a small bound
     gain under bounded buffering), so deployments that care about the
     measured figure can demand it.

Because transfers are DMA (they never occupy a PU), a lower bound
translates directly into a higher saturated processing rate; replication
costs only duplicated crossbar weights, which the capacity constraint
already polices.

The returned assignment maps node ids of the *replicated* graph:
``meta["replicated_graph"]`` carries that graph, ``meta["replicas"]``
the base-node replica counts.  ``schedule_replicated`` is the
convenience wrapper returning ``(replicated_graph, assignment)``.

Incremental probes
------------------
One scheduling pass evaluates dozens of replica variants, and a budget
sweep (the replication benchmark) re-evaluates every budget's prefix
from scratch.  All candidate evaluation therefore runs through a
*probe session* cached on the base graph (``Graph.scratch``), keyed by
(cost model, fleet, inner scheduler): each distinct replica-count
signature is derived, scheduled and load-vectored exactly once, and —
because the session hands back one shared graph object per signature —
the derived graph's compiled ``SimContext`` (seeded from the base
graph's, see ``core.simcontext``) and its content-keyed
``measured_rate`` memo survive across ``validate_rate`` probes, budget
sweeps and benchmark rows alike.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

from ..cost import CostModel, PUSpec
from ..graph import Graph, MultiTenantGraph, Node, PUType
from .base import Assignment, ScheduleError, Scheduler
from .lblp import LBLPScheduler
from .lblp_mt import LBLPMTScheduler

from ..simcontext import MEMO_CAP as _MEMO_CAP  # shared ctx.memo bound


def _weights_sig(g: Graph) -> tuple:
    """Tenant serving weights as a hashable content signature (empty on
    single-model graphs).  Weight changes do not invalidate graph-level
    caches by design, so every cache keyed on graph scratch/ctx.memo
    whose value depends on the fair-queueing interleave must carry it."""
    if isinstance(g, MultiTenantGraph):
        return tuple(sorted(g.tenant_weights.items()))
    return ()


def estimated_gain(g: Graph, node: Node, k: int, cm: CostModel,
                   pus: Sequence[PUSpec], load: Dict[int, float],
                   in_flight: Optional[int] = None) -> float:
    """Transfer-aware analytic estimate of the net relative pipeline-
    interval gain from widening ``node``'s replica group to ``k``.

    Optimistic on the load side, charged on the transfer side:

    * **bound gain** — widening from ``k-1`` to ``k`` replicas frees
      ``t/(k-1) - t/k`` amortized seconds/frame from the PU holding the
      probed replica; even under a perfect re-balance the bound cannot
      drop below the mean load of the node's compatible PU pool
      (amortized total load is conserved by replication).
    * **transfer penalty** — the new replica serves ``1/k`` of the
      frames from a PU its producers and consumers were not placed for,
      so those frames pay cross-PU hand-offs (inputs + output).
      Transfers are DMA (they never occupy a PU, hence never move the
      analytic bound) but they lengthen sojourns, and under a bounded
      in-flight budget ``B`` Little's law converts added sojourn into
      interval: the charge is ``xfer / (k * B)`` seconds/frame.

    Returns the net gain as a fraction of the current bound.  A value
    <= 0 marks a candidate that cannot plausibly pay off — heavy
    activations around a light node — which lets the greedy loop skip
    the full probe (inner schedule + load vector) for it.  The estimate
    prunes, it never accepts: kept candidates still go through the
    probe, the lexicographic test and the final ``min_gain`` revert, so
    the lblp-r >= lblp guarantee is untouched.
    """
    if k < 2:
        raise ScheduleError(f"estimated_gain wants a widened group, got k={k}")
    bound = max(load.values()) if load else 0.0
    if bound <= 0:
        return 0.0
    t = cm.time(node)
    freed = t / (k - 1) - t / k
    pool = [p for p in pus if p.pu_type == node.pu_type] or list(pus)
    pool_ids = {p.pu_id for p in pool}
    pool_mean = (sum(v for pid, v in load.items() if pid in pool_ids)
                 / max(len(pool), 1))
    bound_gain = bound - max(bound - freed, pool_mean)
    xfer = cm.transfer(node, same_pu=False)
    for pid_ in g.predecessors(node.node_id):
        xfer += cm.transfer(g.nodes[pid_], same_pu=False)
    budget = in_flight if in_flight is not None else len(pus) + 2
    penalty = xfer / (k * max(budget, 1))
    return (bound_gain - penalty) / bound


def replication_candidates(g: Graph, a: Assignment, load: Dict[int, float],
                           cm: CostModel, pus: Sequence[PUSpec],
                           counts: Dict[int, int],
                           pu: Optional[int] = None,
                           node_filter=None,
                           limit: Optional[int] = None,
                           gain_model: bool = True
                           ) -> Tuple[list, int]:
    """Widening candidates ``(base_id, new_count)`` on one PU of the
    (possibly already replicated) serving graph ``g`` under mapping
    ``a`` — the selection loop shared by the lblp-r greedy search and
    the serving-tier autoscaler.

    ``pu`` defaults to the fleet bottleneck (max load, lowest id on
    ties); ``node_filter`` restricts the scan (e.g. to one tenant's
    nodes); candidates are ordered heaviest amortized frame-time first
    (instance-id tie-break, replica instances deduplicated to their
    group base), capped at the compatible sub-fleet width, and — with
    ``gain_model`` — pruned by :func:`estimated_gain`; the second
    return value counts the pruned bases.
    """
    if pu is None:
        pu = max(load, key=lambda p: (load[p], -p))
    n_by_type = {pt: sum(1 for p in pus if p.pu_type is pt) for pt in PUType}
    nodes = [g.nodes[nid] for nid, pid in a.mapping.items()
             if pid == pu and not g.nodes[nid].is_free()]
    if node_filter is not None:
        nodes = [n for n in nodes if node_filter(n)]
    nodes.sort(key=lambda n: (-cm.frame_time(n), n.node_id))
    out: list = []
    pruned = 0
    seen = set()
    for node in nodes:
        base = (node.node_id if node.replica_group is None
                else node.replica_group)
        if base in seen:
            continue
        seen.add(base)
        k_new = counts.get(base, 1) + 1
        # wider than the compatible sub-fleet is pure weight waste
        if k_new > max(n_by_type.get(g.nodes[base].pu_type, 0), 1):
            continue
        if gain_model and estimated_gain(g, g.nodes[base], k_new, cm, pus,
                                         load) <= 0.0:
            pruned += 1
            continue
        out.append((base, k_new))
        if limit is not None and len(out) >= limit:
            break
    return out, pruned


class ProbeSession:
    """Replica-variant probe cache for one (base graph, cm, fleet,
    inner scheduler) combination; see module docstring.

    Consumed beyond this module by ``ElasticSession.set_replicas`` and
    the serving control plane, so the entry shape is API:
    :meth:`probe` returns a dict with ``"graph"`` (the derived,
    possibly replicated graph — one shared object per signature),
    ``"assignment"`` (the inner schedule over it, shared — copy before
    mutating), ``"load"`` (per-PU amortized load) and ``"vec"`` (the
    descending-sorted load vector; lexicographically smaller == better
    balanced)."""

    def __init__(self, g: Graph, cm: CostModel, pus: Sequence[PUSpec],
                 inner: Scheduler) -> None:
        self.g = g
        self.cm = cm
        self.pus = list(pus)
        self.inner = inner
        self._variants: Dict[tuple, dict] = {}

    @staticmethod
    def signature(counts: Dict[int, int]) -> tuple:
        return tuple(sorted((k, v) for k, v in counts.items() if v > 1))

    def probe(self, counts: Dict[int, int]) -> dict:
        """Derived graph + inner schedule + load figures for ``counts``,
        computed once per signature and shared thereafter."""
        key = self.signature(counts)
        e = self._variants.get(key)
        if e is None:
            g_v = self.g.with_replicas(dict(counts)) if key else self.g
            a = self.inner.schedule(g_v, self.pus)
            load = a.load(g_v, self.cm)
            # sorted descending: lexicographic "smaller" == better balance
            vec = tuple(sorted(load.values(), reverse=True))
            e = self._variants[key] = {
                "graph": g_v, "assignment": a, "load": load, "vec": vec,
            }
        return e

    @staticmethod
    def for_graph(g: Graph, cm: CostModel, pus: Sequence[PUSpec],
                  inner: Scheduler) -> "ProbeSession":
        key = ("lblp-r-probe", type(cm), cm.profile, inner.name,
               getattr(inner, "branch_constraint", None),
               _weights_sig(g),
               tuple((p.pu_id, p.pu_type, p.speed, p.weight_capacity)
                     for p in pus))
        sess = g.scratch().get(key)
        if sess is None:
            sess = g.scratch()[key] = ProbeSession(g, cm, pus, inner)
        return sess


class LBLPRScheduler(Scheduler):
    name = "lblp-r"

    def __init__(self, cost_model=None, branch_constraint: bool = True,
                 replica_budget: Optional[int] = None,
                 min_gain: float = 0.02,
                 validate_rate: Optional[int] = None,
                 sim_engine: str = "exact",
                 gain_model: bool = True) -> None:
        super().__init__(cost_model)
        self.branch_constraint = branch_constraint
        #: max number of extra replicas to add; None -> fleet size
        self.replica_budget = replica_budget
        #: minimum relative bound improvement to accept the replication
        self.min_gain = min_gain
        #: prune probe candidates whose transfer-aware analytic gain
        #: estimate is <= 0 before running the inner schedule for them
        #: (meta["probes_pruned"] counts the drops)
        self.gain_model = gain_model
        #: simulate both candidates for this many frames and revert if the
        #: replicated schedule's measured rate regresses (None = bound only)
        self.validate_rate = validate_rate
        #: simulation engine for the validation probes ("exact" default;
        #: benchmarks pass "periodic" — both candidates are always
        #: measured with the same engine, so the accept/revert decision
        #: is self-consistent)
        self.sim_engine = sim_engine

    def _inner(self, g: Graph) -> Scheduler:
        if isinstance(g, MultiTenantGraph) and len(g.tenants) > 1:
            return LBLPMTScheduler(self.cm, self.branch_constraint)
        return LBLPScheduler(self.cm, self.branch_constraint)

    @staticmethod
    def _bound(a: Assignment, g: Graph, cm: CostModel) -> float:
        load = a.load(g, cm)
        return max(load.values()) if load else 0.0

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        if g.replica_groups():
            raise ScheduleError(
                "lblp-r wants the unreplicated base graph; it derives "
                "replica counts itself (meta['replicas'])")
        cm = self.cm
        inner = self._inner(g)
        budget = (self.replica_budget if self.replica_budget is not None
                  else len(pus))

        sess = ProbeSession.for_graph(g, cm, pus, inner)
        counts: Dict[int, int] = {}
        base_e = sess.probe(counts)
        base_a = base_e["assignment"]
        base_bound = max(base_e["load"].values()) if base_e["load"] else 0.0
        best_g: Graph = g
        best_a = base_a
        best_vec = base_e["vec"]
        best_load = base_e["load"]

        extra = 0
        pruned = 0
        while extra < budget:
            load = best_load
            cands, dropped = replication_candidates(
                best_g, best_a, load, cm, pus, counts,
                gain_model=self.gain_model)
            pruned += dropped
            improved = False
            for base, k_new in cands:
                try_counts = {**counts, base: k_new}
                e = sess.probe(try_counts)
                if e["vec"] < best_vec:
                    counts = try_counts
                    best_g, best_a = e["graph"], e["assignment"]
                    best_vec, best_load = e["vec"], e["load"]
                    improved = True
                    break
            if not improved:
                break
            extra += 1

        best_bound = best_vec[0] if best_vec else 0.0
        if not best_bound < base_bound * (1 - self.min_gain):
            # gain never materialized: replication is not free (duplicated
            # weights, extra transfers) — fall back to plain LBLP
            counts, best_g, best_a, extra = {}, g, base_a, 0
            best_bound = base_bound
        elif self.validate_rate and counts:
            if measured_rate(best_g, best_a, cm, self.validate_rate,
                             engine=self.sim_engine) \
                    < measured_rate(g, base_a, cm, self.validate_rate,
                                    engine=self.sim_engine):
                counts, best_g, best_a, extra = {}, g, base_a, 0
                best_bound = base_bound

        return Assignment(
            mapping=dict(best_a.mapping),
            pus=list(pus),
            algorithm=self.name,
            meta={**best_a.meta,
                  "base_algorithm": inner.name,
                  "replicas": dict(counts),
                  "extra_replicas": extra,
                  "replicated_graph": best_g,
                  "bound_interval": best_bound,
                  "probes_pruned": pruned},
        )


def measured_rate(g: Graph, a: Assignment, cm: Optional[CostModel],
                  frames: int, sim=None, engine: str = "exact") -> float:
    """Simulated saturated processing rate of ``a`` over ``g`` (aggregate
    tenant rate on multi-tenant unions) — the validation metric lblp-r
    and the replication benchmark share.

    Runs only the saturated-throughput pass (the latency and isolated
    passes of ``run()`` cost ~2x more simulator work and do not affect
    the rate); the values are identical to ``SimResult.rate`` /
    ``sum(tenants[*].rate)`` from a full ``run()`` at the same frames.

    Callers probing the same graph repeatedly can pass a prebuilt
    ``sim`` to share one engine; otherwise one is built here — cheap
    either way, because the compiled ``SimContext`` (topo order, bottom
    levels, adjacency) is cached on the graph object and the
    per-assignment ``ExecPlan`` on the context, so repeated probes stop
    re-deriving graph structure.  ``engine`` selects the simulation
    engine for freshly built simulators (see
    :func:`repro.core.make_simulator`).
    """
    # imported here: simulator -> schedulers.base is the layering; this
    # validation hook is the one place the arrow points back
    from .. import make_simulator
    if sim is None:
        sim = make_simulator(g, cm, engine=engine)
    # the rate is a deterministic function of (mapping, fleet, frames,
    # engine) over this context's graph: memoize by content, because the
    # lblp-r budget sweep re-derives identical candidate schedules as
    # fresh objects (the id-keyed ExecPlan cache cannot see that)
    memo = getattr(sim, "_ctx", None) and sim._ctx.memo
    key = None
    if memo is not None:
        key = ("measured_rate", type(sim).__name__, sim.mode, frames,
               _weights_sig(g),
               tuple(sorted(a.mapping.items())),
               tuple((p.pu_id, p.pu_type, p.speed) for p in a.pus))
        hit = memo.get(key)
        if hit is not None:
            # LRU touch: re-insert so the entry survives eviction while
            # a scheduling pass keeps probing it
            del memo[key]
            memo[key] = hit
            return hit
    if isinstance(g, MultiTenantGraph) and len(g.tenants) > 1:
        _, completions, _, _, _ = sim._run_streams(
            a, {t: frames for t in g.tenants},
            in_flight=len(a.pus) + 2, light=True)
        total = 0.0
        for comps in completions.values():
            interval, _ = sim._steady_state(comps)
            total += 1.0 / interval if interval > 0 else math.inf
    else:
        _, completions, _, _, _ = sim._run_streams(
            a, frames=frames, in_flight=len(a.pus) + 2, light=True)
        interval, _ = sim._steady_state(completions[next(iter(completions))])
        total = 1.0 / interval if interval > 0 else math.inf
    if key is not None:
        while len(memo) >= _MEMO_CAP:
            # bounded LRU: evict the stalest entry, never the whole
            # cache (a mid-search wipe used to throw away every probe
            # of the current scheduling pass)
            memo.pop(next(iter(memo)))
        memo[key] = total
    return total


def schedule_replicated(g: Graph, pus: Sequence[PUSpec],
                        cost_model: Optional[CostModel] = None,
                        **kw) -> Tuple[Graph, Assignment]:
    """Run lblp-r and return ``(replicated_graph, assignment)`` — the pair
    the simulator needs (the mapping refers to the replicated graph)."""
    a = LBLPRScheduler(cost_model, **kw).schedule(g, pus)
    return a.meta["replicated_graph"], a
