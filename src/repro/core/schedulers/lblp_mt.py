"""LBLP-MT — multi-tenant Load-Balance Longest-Path co-scheduling.

The paper's Algorithm 1 maps one CNN onto the fleet; under multi-tenant
serving (several models resident at once, each with its own frame stream)
running it verbatim on the disjoint union is biased: the union's single
longest path belongs to the *heaviest* tenant, so only that tenant's
critical path receives the LP-first treatment and the others are placed
as an afterthought.

LBLP-MT generalizes steps 1-3 to the union:

  1. Identify every tenant's longest path (disjoint components make the
     per-tenant LP exact on the union's topological order).
  2. Per PU type, round-robin across tenants — heaviest-LP tenant first —
     taking each tenant's LP nodes in descending execution time, and
     assign min-load with the capacity constraint.  Interleaving keeps
     every tenant's critical path spread over the least-loaded PUs
     instead of letting one tenant monopolize them.
  3. Non-LP nodes of all tenants follow, sorted descending, with the
     parallel-branch constraint evaluated *within* a tenant only: across
     tenants every pair is trivially parallel, so the intra-graph branch
     separation rule would otherwise degenerate into noise.

On a single-model graph LBLP-MT reduces exactly to LBLP.

Tenant weights (serving priority)
---------------------------------
Per-tenant weights — from ``MultiTenantGraph.tenant_weight`` or the
``tenant_weights`` constructor override — scale each tenant's claim in
the interleave: tenants are ordered by *weighted* longest-path time, so
a weight-2 tenant's critical path picks least-loaded PUs before an
equally heavy weight-1 tenant's.  The same weights drive the
simulator's weighted fair queueing (a weight-w tenant receives w times
the fleet share), so scheduler and runtime agree on who the priority
tenants are.  All weights defaulting to 1.0 reproduces the historical
unweighted behaviour exactly.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from ..cost import PUSpec
from ..graph import Graph, MultiTenantGraph, Node, PUType
from .base import Assignment, Scheduler, schedulable_nodes
from .lblp import LBLPScheduler


class LBLPMTScheduler(Scheduler):
    name = "lblp-mt"

    def __init__(self, cost_model=None, branch_constraint: bool = True,
                 tenant_weights: Optional[Dict[str, float]] = None) -> None:
        super().__init__(cost_model)
        self.branch_constraint = branch_constraint
        #: optional per-tenant weight override; tenants absent here fall
        #: back to the graph's own ``tenant_weight`` (default 1.0)
        self.tenant_weights = dict(tenant_weights or {})

    def _tenant_weight(self, g: MultiTenantGraph, tenant: str) -> float:
        w = self.tenant_weights.get(tenant)
        return w if w is not None else g.tenant_weight(tenant)

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        if not isinstance(g, MultiTenantGraph) or len(g.tenants) <= 1:
            a = LBLPScheduler(self.cm, self.branch_constraint).schedule(g, pus)
            a.algorithm = self.name
            return a
        cm = self.cm
        mapping: Dict[int, int] = {}
        load: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        weights: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        spills: List[int] = []

        # Step 1: per-tenant longest paths, heaviest tenant first.
        # Fleet-independent, so cached on the graph (cleared on mutation)
        # — lblp-r probes and elastic events re-schedule one union often.
        lp_key = ("lblp-mt-lp", type(cm), cm.profile)
        hit = g.scratch().get(lp_key)
        if hit is None:
            lp_of = {t: g.tenant_longest_path(t, lambda n: cm.time(n))
                     for t in g.tenants}
            lp_time = {t: sum(cm.time(g.nodes[n]) for n in lp_of[t])
                       for t in g.tenants}
            g.scratch()[lp_key] = (lp_of, lp_time)
        else:
            lp_of, lp_time = hit
        # weighted priority order: a tenant's claim on the least-loaded
        # PUs scales with weight * critical-path time (weight 1.0
        # everywhere == the historical unweighted order)
        wt = {t: self._tenant_weight(g, t) for t in g.tenants}
        tenant_order = sorted(g.tenants,
                              key=lambda t: (-lp_time[t] * wt[t], t))
        lp_set = {n for lp in lp_of.values() for n in lp}

        def same_tenant_parallel(a: int, b: int) -> bool:
            # branch separation only matters within a tenant: across
            # tenants every pair is trivially parallel.
            return (g.nodes[a].meta.get("tenant") == g.nodes[b].meta.get("tenant")
                    and g.is_parallel(a, b))

        conflicts = same_tenant_parallel if self.branch_constraint else None
        on_pu: Dict[int, List[int]] = {p.pu_id: [] for p in pus}

        def assign(node: Node, candidates: List[PUSpec]) -> None:
            self._assign_min_load(node, candidates, mapping, load, weights,
                                  spills, conflicts, on_pu)

        # Step 2: interleaved LP assignment, per PU type.
        for pu_type in (PUType.IMC, PUType.DPU):
            queues: List[List[Node]] = []
            for t in tenant_order:
                batch = [g.nodes[n] for n in lp_of[t]
                         if not g.nodes[n].is_free()
                         and g.nodes[n].pu_type == pu_type]
                batch.sort(key=lambda n: (-cm.time(n), n.node_id))
                queues.append(batch)
            depth = max((len(q) for q in queues), default=0)
            for rank in range(depth):
                for q in queues:
                    if rank < len(q):
                        node = q[rank]
                        assign(node, self._compatible(node, pus))

        # Step 3: non-LP nodes of all tenants, descending execution time.
        rest = [n for n in schedulable_nodes(g) if n.node_id not in lp_set]
        for pu_type in (PUType.IMC, PUType.DPU):
            batch = [n for n in rest if n.pu_type == pu_type]
            batch.sort(key=lambda n: (-cm.time(n), n.node_id))
            for node in batch:
                assign(node, self._compatible(node, pus))

        return Assignment(
            mapping=mapping,
            pus=list(pus),
            algorithm=self.name,
            meta={
                "longest_paths": {t: lp_of[t] for t in tenant_order},
                "capacity_spills": spills,
                "tenant_weights": wt,
            },
        )
