"""Branch-and-bound optimal load balancer (beyond-paper quality bound).

Minimizes the pipeline bottleneck ``max_pu(total assigned time)`` —
the quantity that determines steady-state processing rate — exactly,
subject to PU-type compatibility and weight capacity.  Exponential in
the worst case; intended for graphs up to ~25 schedulable nodes (ResNet8
easily, ResNet18 with the default beam cap).  Used in tests/benchmarks to
measure how far LBLP sits from the optimum.

The search assigns nodes in descending execution-time order (strongest
pruning), with two bounds:
  * partial bottleneck >= incumbent  -> prune
  * (sum of remaining time)/|PUs| + ... relaxation cannot beat incumbent -> prune
Symmetry: identical empty PUs are interchangeable; only the first empty
PU of each type is branched on.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from ..cost import PUSpec
from ..graph import Graph, PUType
from .base import Assignment, Scheduler, schedulable_nodes


class OptimalScheduler(Scheduler):
    name = "optimal"

    def __init__(self, cost_model=None, node_limit: int = 26,
                 max_expansions: int = 2_000_000) -> None:
        super().__init__(cost_model)
        self.node_limit = node_limit
        self.max_expansions = max_expansions

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        cm = self.cm
        nodes = schedulable_nodes(g)
        if len(nodes) > self.node_limit:
            raise ValueError(
                f"optimal scheduler limited to {self.node_limit} nodes "
                f"(got {len(nodes)}); use lblp/heft for larger graphs"
            )
        # group nodes by type; the bottleneck decomposes per-type only if
        # fleets are disjoint (they are: IMC vs DPU), so solve separately.
        mapping: Dict[int, int] = {}
        best_bneck = 0.0
        for pu_type in (PUType.IMC, PUType.DPU):
            sub = [n for n in nodes if n.pu_type == pu_type]
            fleet = [p for p in pus if p.pu_type == pu_type]
            if not sub:
                continue
            if not fleet:
                fleet = [p for p in pus
                         if not math.isinf(cm.time(sub[0], p.pu_type, p.speed))]
            sub.sort(key=lambda n: (-cm.time(n), n.node_id))
            times = [cm.time(n) for n in sub]
            wts = [n.weight_bytes for n in sub]
            caps = [p.capacity(cm.profile) for p in fleet]

            incumbent = [math.inf]
            best_assign: List[Optional[List[int]]] = [None]
            loads = [0.0] * len(fleet)
            used_w = [0.0] * len(fleet)
            assign = [0] * len(sub)
            expansions = [0]

            suffix = [0.0] * (len(sub) + 1)
            for i in range(len(sub) - 1, -1, -1):
                suffix[i] = suffix[i + 1] + times[i]

            n_sub, n_fleet = len(sub), len(fleet)

            def dfs(i: int) -> None:
                if expansions[0] > self.max_expansions:
                    return
                expansions[0] += 1
                if i == n_sub:
                    b = max(loads)
                    if b < incumbent[0]:
                        incumbent[0] = b
                        best_assign[0] = list(assign)
                    return
                # relaxation bound: even perfectly spreading the rest can't
                # get below max(current max-free average, biggest single
                # item); loads are non-negative so max(loads) needs no
                # emptiness/zero guard
                lb = max(
                    max(loads),
                    (sum(loads) + suffix[i]) / n_fleet,
                    times[i],
                )
                if lb >= incumbent[0] - 1e-15:
                    return
                seen_empty = False
                order = sorted(range(n_fleet), key=loads.__getitem__)
                for j in order:
                    if loads[j] == 0.0:
                        if seen_empty:
                            continue  # symmetry break
                        seen_empty = True
                    if used_w[j] + wts[i] > caps[j] * (1 + 1e-9):
                        continue
                    loads[j] += times[i]
                    used_w[j] += wts[i]
                    assign[i] = j
                    dfs(i + 1)
                    loads[j] -= times[i]
                    used_w[j] -= wts[i]

            dfs(0)
            if best_assign[0] is None:
                raise RuntimeError("branch-and-bound found no feasible packing")
            for n, j in zip(sub, best_assign[0]):
                mapping[n.node_id] = fleet[j].pu_id
            best_bneck = max(best_bneck, incumbent[0])

        return Assignment(mapping=mapping, pus=list(pus), algorithm=self.name,
                          meta={"optimal_bottleneck": best_bneck})
