"""Scheduler registry.

Paper algorithms: lblp (Alg. 1), wb (Alg. 2), rr, rd (§IV).
Beyond-paper:     heft, cpop ([12], related work), optimal (B&B bound),
                  lblp-x (our improved variant).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

from ..cost import CostModel
from .base import Assignment, ScheduleError, Scheduler
from .lblp import LBLPScheduler
from .lblp_mt import LBLPMTScheduler
from .lblp_r import LBLPRScheduler, schedule_replicated
from .rd import RDScheduler
from .rr import RRScheduler
from .wb import WBScheduler

_REGISTRY: Dict[str, Callable[..., Scheduler]] = {
    "lblp": LBLPScheduler,
    "lblp-mt": LBLPMTScheduler,
    "lblp-r": LBLPRScheduler,
    "wb": WBScheduler,
    "rr": RRScheduler,
    "rd": RDScheduler,
}


def register(name: str, factory: Callable[..., Scheduler]) -> None:
    _REGISTRY[name] = factory


def get_scheduler(name: str, cost_model: Optional[CostModel] = None,
                  **kw) -> Scheduler:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scheduler '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](cost_model, **kw)


def available() -> list:
    return sorted(_REGISTRY)


# Late registrations (importable lazily to keep base deps minimal).
def _register_extras() -> None:
    from .heft import CPOPScheduler, HEFTScheduler
    from .lblp_x import LBLPXScheduler
    from .optimal import OptimalScheduler

    register("heft", HEFTScheduler)
    register("cpop", CPOPScheduler)
    register("optimal", OptimalScheduler)
    register("lblp-x", LBLPXScheduler)


try:  # extras are part of the library; guard only against partial checkouts
    _register_extras()
except ImportError:  # pragma: no cover
    pass

__all__ = [
    "Assignment",
    "ScheduleError",
    "Scheduler",
    "LBLPScheduler",
    "LBLPMTScheduler",
    "LBLPRScheduler",
    "WBScheduler",
    "RRScheduler",
    "RDScheduler",
    "get_scheduler",
    "register",
    "available",
    "schedule_replicated",
]
