"""Load-Balance Longest-Path (LBLP) — the paper's Algorithm 1.

Steps (verbatim from the paper):

  1. Identify the Longest Path (LP): the sequence of nodes forming the
     path with the highest total execution time.
  2. For each processing type (IMC/DPU), sort the LP nodes in descending
     order of execution time.
  3. Assign each LP node to the compatible PU with the smallest total
     assigned execution time; update that PU's total.
  4. Repeat step 3 for the non-LP nodes (also sorted descending), while
     enforcing the parallel-branch constraint: nodes on parallel branches
     are assigned, if possible, to *different* PUs (maximizes pipeline
     parallelism).

Our implementation additionally respects the IMC weight-capacity
constraint (Table I normalizes per-PU "weights area" to 100%, implying a
hard capacity): a PU whose crossbars cannot hold the node's weights is
skipped; if no compatible PU fits, capacity is waived for that node (the
emulator spills to DRAM) and the event is recorded in ``meta``.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

from ..cost import PUSpec
from ..graph import Graph, Node, PUType
from .base import Assignment, Scheduler, schedulable_nodes


class LBLPScheduler(Scheduler):
    name = "lblp"

    def __init__(self, cost_model=None, branch_constraint: bool = True) -> None:
        super().__init__(cost_model)
        self.branch_constraint = branch_constraint

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        cm = self.cm
        mapping: Dict[int, int] = {}
        load: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        weights: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        spills: List[int] = []

        # Step 1: longest path by execution time (on native PU type).
        # Fleet-independent, so cached on the graph (cleared on mutation)
        # — elastic sessions and lblp-r probes re-schedule one graph many
        # times over changing fleets.
        lp_key = ("lblp-lp", type(cm), cm.profile)
        lp = g.scratch().get(lp_key)
        if lp is None:
            lp = g.scratch()[lp_key] = g.longest_path(lambda n: cm.time(n))
        lp_set = set(lp)

        # prefer PUs holding no node parallel to this one
        conflicts = g.is_parallel if self.branch_constraint else None
        on_pu: Dict[int, List[int]] = {p.pu_id: [] for p in pus}

        def assign(node: Node, candidates: List[PUSpec]) -> None:
            self._assign_min_load(node, candidates, mapping, load, weights,
                                  spills, conflicts, on_pu)

        # Steps 2-3: LP nodes, per type, descending execution time.
        lp_nodes = [g.nodes[n] for n in lp if not g.nodes[n].is_free()]
        for pu_type in (PUType.IMC, PUType.DPU):
            batch = [n for n in lp_nodes if n.pu_type == pu_type]
            batch.sort(key=lambda n: (-cm.time(n), n.node_id))
            for node in batch:
                assign(node, self._compatible(node, pus))

        # Step 4: non-LP nodes, same procedure (+ branch constraint).
        rest = [n for n in schedulable_nodes(g) if n.node_id not in lp_set]
        for pu_type in (PUType.IMC, PUType.DPU):
            batch = [n for n in rest if n.pu_type == pu_type]
            batch.sort(key=lambda n: (-cm.time(n), n.node_id))
            for node in batch:
                assign(node, self._compatible(node, pus))

        return Assignment(
            mapping=mapping,
            pus=list(pus),
            algorithm=self.name,
            meta={"longest_path": lp, "capacity_spills": spills},
        )
