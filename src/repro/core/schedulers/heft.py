"""HEFT and CPOP — related-work baselines (paper ref [12], Topcuoglu et al.).

These are *insertion-based list schedulers* that also produce node start
times; we keep only the node->PU mapping (the simulator re-derives timing
under the compute-and-forward pipeline model, for an apples-to-apples
comparison with the paper's algorithms).

HEFT: nodes ranked by upward rank (mean exec + max(comm + succ rank));
each node is placed on the PU minimizing its earliest finish time (EFT)
with insertion into idle gaps.

CPOP: critical-path nodes are pinned to the single PU minimizing the
total critical-path time; other nodes placed by EFT.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

from ..cost import PUSpec
from ..graph import Graph, Node, PUType
from .base import Assignment, Scheduler, schedulable_nodes


class _EFTState:
    """Per-PU schedule state with gap insertion."""

    def __init__(self, pus: Sequence[PUSpec]) -> None:
        self.slots: Dict[int, List[Tuple[float, float]]] = {p.pu_id: [] for p in pus}

    def earliest_start(self, pid: int, ready: float, dur: float) -> float:
        """Earliest start >= ready on PU pid, allowing gap insertion."""
        slots = self.slots[pid]
        t = ready
        for (s, e) in slots:
            if t + dur <= s:
                return t
            t = max(t, e)
        return t

    def commit(self, pid: int, start: float, dur: float) -> None:
        slots = self.slots[pid]
        slots.append((start, start + dur))
        slots.sort()


class HEFTScheduler(Scheduler):
    name = "heft"

    def _mean_time(self, node: Node, pus: Sequence[PUSpec]) -> float:
        ts = [
            self.cm.time(node, p.pu_type, p.speed)
            for p in pus
            if not math.isinf(self.cm.time(node, p.pu_type, p.speed))
        ]
        return sum(ts) / len(ts) if ts else 0.0

    def _upward_ranks(self, g: Graph, pus: Sequence[PUSpec]) -> Dict[int, float]:
        rank: Dict[int, float] = {}
        for nid in reversed(g.topo_order()):
            node = g.nodes[nid]
            w = 0.0 if node.is_free() else self._mean_time(node, pus)
            best = 0.0
            for s in g.successors(nid):
                comm = self.cm.transfer(node, same_pu=False) / 2.0  # mean comm
                best = max(best, comm + rank[s])
            rank[nid] = w + best
        return rank

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        cm = self.cm
        rank = self._upward_ranks(g, pus)
        mapping: Dict[int, int] = {}
        weights: Dict[int, float] = {p.pu_id: 0.0 for p in pus}
        finish: Dict[int, float] = {}
        state = _EFTState(pus)

        order = sorted(
            (n for n in schedulable_nodes(g)),
            key=lambda n: (-rank[n.node_id], n.node_id),
        )
        # free nodes finish at time 0 wherever needed
        for n in g.nodes.values():
            if n.is_free():
                finish[n.node_id] = 0.0

        # HEFT requires a topologically consistent order; upward rank
        # guarantees ancestors rank higher only with positive weights, so
        # enforce readiness explicitly.
        scheduled = set(finish)
        pending = list(order)
        while pending:
            node = next(
                p for p in pending
                if all(q in scheduled or q in finish for q in g.predecessors(p.node_id))
            )
            pending.remove(node)
            best = None
            for p in self._compatible(node, pus):
                if not self._fits(node, p, weights):
                    continue
                dur = cm.time(node, p.pu_type, p.speed)
                ready = 0.0
                for q in g.predecessors(node.node_id):
                    comm = cm.transfer(g.nodes[q], same_pu=(mapping.get(q) == p.pu_id))
                    ready = max(ready, finish[q] + comm)
                start = state.earliest_start(p.pu_id, ready, dur)
                eft = start + dur
                if best is None or eft < best[0]:
                    best = (eft, start, dur, p)
            if best is None:  # capacity waiver
                p = self._compatible(node, pus)[0]
                dur = cm.time(node, p.pu_type, p.speed)
                best = (dur, 0.0, dur, p)
            eft, start, dur, p = best
            mapping[node.node_id] = p.pu_id
            weights[p.pu_id] += node.weight_bytes
            finish[node.node_id] = eft
            state.commit(p.pu_id, start, dur)
            scheduled.add(node.node_id)

        return Assignment(mapping=mapping, pus=list(pus), algorithm=self.name)


class CPOPScheduler(HEFTScheduler):
    name = "cpop"

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        cm = self.cm
        # critical path by execution time (native PU)
        cp = set(g.longest_path(lambda n: cm.time(n)))
        # pin CP nodes per type to the fastest compatible PU for that type
        pin: Dict[PUType, int] = {}
        for t in (PUType.IMC, PUType.DPU):
            cands = [p for p in pus if p.pu_type == t]
            if cands:
                pin[t] = max(cands, key=lambda p: p.speed).pu_id

        base = super().schedule(g, pus)
        mapping = dict(base.mapping)
        for nid in cp:
            node = g.nodes[nid]
            if node.is_free():
                continue
            pid = pin.get(node.pu_type)
            if pid is not None:
                mapping[nid] = pid
        return Assignment(mapping=mapping, pus=list(pus), algorithm=self.name,
                          meta={"critical_path": sorted(cp)})
