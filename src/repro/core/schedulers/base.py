"""Scheduler API: node-to-PU assignment production and validation.

A *schedule* here is purely the static mapping the paper studies
(``Assignment``: node_id -> pu_id).  Temporal behaviour (rate, latency,
utilization) is derived by ``repro.core.simulator`` from the mapping.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence

from ..cost import CostModel, PUSpec
from ..graph import Graph, Node, OpKind, PUType


class ScheduleError(ValueError):
    pass


@dataclass
class Assignment:
    """A node->PU mapping plus the context it was produced for."""

    mapping: Dict[int, int]                  # node_id -> pu_id
    pus: List[PUSpec]
    algorithm: str = "unknown"
    meta: dict = field(default_factory=dict)

    def pu_of(self, node_id: int) -> int:
        return self.mapping[node_id]

    def nodes_on(self, pu_id: int) -> List[int]:
        return sorted(n for n, p in self.mapping.items() if p == pu_id)

    def pu_by_id(self, pu_id: int) -> PUSpec:
        for p in self.pus:
            if p.pu_id == pu_id:
                return p
        raise KeyError(pu_id)

    # -- static per-PU aggregates ------------------------------------------
    def load(self, g: Graph, cm: CostModel) -> Dict[int, float]:
        """Total assigned execution time per PU (the paper's load)."""
        out = {p.pu_id: 0.0 for p in self.pus}
        for nid, pid in self.mapping.items():
            pu = self.pu_by_id(pid)
            out[pid] += cm.time(g.nodes[nid], pu.pu_type, pu.speed)
        return out

    def weights(self, g: Graph) -> Dict[int, float]:
        out = {p.pu_id: 0.0 for p in self.pus}
        for nid, pid in self.mapping.items():
            out[pid] += g.nodes[nid].weight_bytes
        return out

    def bottleneck(self, g: Graph, cm: CostModel) -> float:
        """max per-PU load == steady-state pipeline interval (1/rate)."""
        return max(self.load(g, cm).values())

    def validate(self, g: Graph, cm: CostModel,
                 check_capacity: bool = True) -> None:
        """Raise unless the mapping is executable on the fleet."""
        unmapped = set(g.nodes) - set(self.mapping)
        unmapped = {n for n in unmapped if not g.nodes[n].is_free()}
        if unmapped:
            raise ScheduleError(f"unmapped nodes: {sorted(unmapped)}")
        for nid, pid in self.mapping.items():
            node = g.nodes[nid]
            pu = self.pu_by_id(pid)
            if math.isinf(cm.time(node, pu.pu_type, pu.speed)):
                raise ScheduleError(
                    f"node {nid} ({node.kind.value}) not executable on "
                    f"{pu.pu_type.value} PU {pid}"
                )
        if check_capacity:
            caps = {p.pu_id: p.capacity(cm.profile) for p in self.pus}
            for pid, w in self.weights(g).items():
                if w > caps[pid] * (1 + 1e-9):
                    raise ScheduleError(
                        f"PU {pid} weight capacity exceeded: {w:.0f} > {caps[pid]:.0f}"
                    )


class Scheduler:
    """Base class.  Subclasses implement :meth:`schedule`."""

    name = "base"

    def __init__(self, cost_model: Optional[CostModel] = None) -> None:
        self.cm = cost_model or CostModel()

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        raise NotImplementedError

    # -- shared helpers -------------------------------------------------------
    def _compatible(self, node: Node, pus: Sequence[PUSpec]) -> List[PUSpec]:
        """PUs that can execute ``node`` at finite cost, preferring the
        node's native type when any exist (paper's placement policy)."""
        native = [p for p in pus if p.pu_type == node.pu_type]
        if native:
            return native
        return [
            p for p in pus
            if not math.isinf(self.cm.time(node, p.pu_type, p.speed))
        ]

    def _fits(self, node: Node, pu: PUSpec, assigned_weights: Mapping[int, float]) -> bool:
        cap = pu.capacity(self.cm.profile)
        return assigned_weights.get(pu.pu_id, 0.0) + node.weight_bytes <= cap * (1 + 1e-9)


def split_fleet(pus: Sequence[PUSpec]) -> Dict[PUType, List[PUSpec]]:
    out: Dict[PUType, List[PUSpec]] = {PUType.IMC: [], PUType.DPU: []}
    for p in pus:
        out[p.pu_type].append(p)
    return out


def schedulable_nodes(g: Graph) -> List[Node]:
    """All nodes that need a PU (drops free INPUT/OUTPUT glue)."""
    return [n for n in g.nodes.values() if not n.is_free()]
