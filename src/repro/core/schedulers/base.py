"""Scheduler API: node-to-PU assignment production and validation.

A *schedule* here is purely the static mapping the paper studies
(``Assignment``: node_id -> pu_id).  Temporal behaviour (rate, latency,
utilization) is derived by ``repro.core.simulator`` from the mapping.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence

from ..cost import CostModel, PUSpec
from ..graph import Graph, Node, PUType


class ScheduleError(ValueError):
    pass


@dataclass
class Assignment:
    """A node->PU mapping plus the context it was produced for."""

    mapping: Dict[int, int]                  # node_id -> pu_id
    pus: List[PUSpec]
    algorithm: str = "unknown"
    meta: dict = field(default_factory=dict)

    def pu_of(self, node_id: int) -> int:
        return self.mapping[node_id]

    def nodes_on(self, pu_id: int) -> List[int]:
        return sorted(n for n, p in self.mapping.items() if p == pu_id)

    def pu_by_id(self, pu_id: int) -> PUSpec:
        for p in self.pus:
            if p.pu_id == pu_id:
                return p
        raise KeyError(pu_id)

    def resolve_graph(self, g: Graph) -> Graph:
        """The graph this mapping actually refers to.

        Graph-transforming schedulers (lblp-r layer replication) map node
        ids of a derived graph stored in ``meta["replicated_graph"]``;
        when a caller passes the base graph, substitute the derived one so
        loads and validation see every mapped node."""
        rg = self.meta.get("replicated_graph")
        if rg is not None and any(nid not in g.nodes for nid in self.mapping):
            return rg
        return g

    # -- static per-PU aggregates ------------------------------------------
    def load(self, g: Graph, cm: CostModel) -> Dict[int, float]:
        """Per-frame assigned execution time per PU (the paper's load).

        Replicated nodes are amortized: a k-way replica serves every k-th
        frame, contributing ``time/k`` (``CostModel.frame_time``).  On an
        unreplicated graph this is exactly the paper's total-time load.
        """
        g = self.resolve_graph(g)
        out = {p.pu_id: 0.0 for p in self.pus}
        for nid, pid in self.mapping.items():
            pu = self.pu_by_id(pid)
            out[pid] += cm.frame_time(g.nodes[nid], pu.pu_type, pu.speed)
        return out

    def weights(self, g: Graph) -> Dict[int, float]:
        g = self.resolve_graph(g)
        out = {p.pu_id: 0.0 for p in self.pus}
        for nid, pid in self.mapping.items():
            out[pid] += g.nodes[nid].weight_bytes
        return out

    def bottleneck(self, g: Graph, cm: CostModel) -> float:
        """max per-PU load == steady-state pipeline interval (1/rate)."""
        return max(self.load(g, cm).values())

    # -- multi-tenant aggregates -------------------------------------------
    def tenant_load(self, g: Graph, cm: CostModel) -> Dict[str, Dict[int, float]]:
        """Per-tenant, per-PU assigned execution time.

        On a :class:`~repro.core.graph.MultiTenantGraph` tenants come from
        the node tags; a plain single-model graph reports one tenant under
        its own name.  Summing over tenants recovers :meth:`load` exactly.
        """
        g = self.resolve_graph(g)
        out: Dict[str, Dict[int, float]] = {}
        for nid, pid in self.mapping.items():
            tenant = g.nodes[nid].meta.get("tenant", g.name)
            pu = self.pu_by_id(pid)
            per_pu = out.setdefault(tenant, {p.pu_id: 0.0 for p in self.pus})
            per_pu[pid] += cm.frame_time(g.nodes[nid], pu.pu_type, pu.speed)
        return out

    def tenant_bottleneck(self, g: Graph, cm: CostModel) -> Dict[str, float]:
        """Per-tenant max per-PU load: each tenant's own pipeline-interval
        lower bound if it ran alone on the fleet slice it was given."""
        return {t: max(per_pu.values())
                for t, per_pu in self.tenant_load(g, cm).items()}

    def validate(self, g: Graph, cm: CostModel,
                 check_capacity: bool = True) -> None:
        """Raise unless the mapping is executable on the fleet."""
        g = self.resolve_graph(g)
        unmapped = set(g.nodes) - set(self.mapping)
        unmapped = {n for n in unmapped if not g.nodes[n].is_free()}
        if unmapped:
            raise ScheduleError(f"unmapped nodes: {sorted(unmapped)}")
        for nid, pid in self.mapping.items():
            node = g.nodes[nid]
            pu = self.pu_by_id(pid)
            if math.isinf(cm.time(node, pu.pu_type, pu.speed)):
                raise ScheduleError(
                    f"node {nid} ({node.kind.value}) not executable on "
                    f"{pu.pu_type.value} PU {pid}"
                )
        if check_capacity:
            caps = {p.pu_id: p.capacity(cm.profile) for p in self.pus}
            for pid, w in self.weights(g).items():
                if w > caps[pid] * (1 + 1e-9):
                    raise ScheduleError(
                        f"PU {pid} weight capacity exceeded: {w:.0f} > {caps[pid]:.0f}"
                    )


class Scheduler:
    """Base class.  Subclasses implement :meth:`schedule`."""

    name = "base"

    def __init__(self, cost_model: Optional[CostModel] = None) -> None:
        self.cm = cost_model or CostModel()

    def schedule(self, g: Graph, pus: Sequence[PUSpec]) -> Assignment:
        raise NotImplementedError

    # -- shared helpers -------------------------------------------------------
    def _compatible(self, node: Node, pus: Sequence[PUSpec]) -> List[PUSpec]:
        """PUs that can execute ``node`` at finite cost, preferring the
        node's native type when any exist (paper's placement policy)."""
        native = [p for p in pus if p.pu_type == node.pu_type]
        if native:
            return native
        return [
            p for p in pus
            if not math.isinf(self.cm.time(node, p.pu_type, p.speed))
        ]

    def _fits(self, node: Node, pu: PUSpec, assigned_weights: Mapping[int, float]) -> bool:
        cap = pu.capacity(self.cm.profile)
        return assigned_weights.get(pu.pu_id, 0.0) + node.weight_bytes <= cap * (1 + 1e-9)

    def _assign_min_load(self, node: Node, candidates: Sequence[PUSpec],
                         mapping: Dict[int, int], load: Dict[int, float],
                         weights: Dict[int, float], spills: List[int],
                         conflicts=None,
                         on_pu: Optional[Dict[int, List[int]]] = None) -> None:
        """Min-load greedy placement with the LBLP capacity-waiver contract:
        a node no PU can hold is still assigned (the emulator spills its
        weights to DRAM) and recorded in ``spills``.  ``conflicts(a, b)``
        optionally marks node pairs to keep on different PUs when possible
        (the parallel-branch constraint; callers scope the predicate).
        ``on_pu`` (pu_id -> assigned node ids, maintained here) makes the
        conflict scan per candidate PU proportional to that PU's own
        nodes instead of the whole mapping; callers that pass it must
        start from a dict consistent with ``mapping``."""
        pool = [p for p in candidates if self._fits(node, p, weights)]
        if not pool:
            pool = list(candidates)  # capacity waiver (spill)
            spills.append(node.node_id)
        if conflicts is not None:
            nid = node.node_id
            if on_pu is not None:
                free = [
                    p for p in pool
                    if not any(conflicts(nid, other)
                               for other in on_pu.get(p.pu_id, ()))
                ]
            else:
                free = [
                    p for p in pool
                    if not any(
                        conflicts(nid, other)
                        for other, pid in mapping.items()
                        if pid == p.pu_id
                    )
                ]
            if free:
                pool = free
        best = min(pool, key=lambda p: (load[p.pu_id], p.pu_id))
        mapping[node.node_id] = best.pu_id
        if on_pu is not None:
            on_pu.setdefault(best.pu_id, []).append(node.node_id)
        # replicas are amortized (frame_time == time on unreplicated graphs)
        load[best.pu_id] += self.cm.frame_time(node, best.pu_type, best.speed)
        weights[best.pu_id] += node.weight_bytes


def split_fleet(pus: Sequence[PUSpec]) -> Dict[PUType, List[PUSpec]]:
    out: Dict[PUType, List[PUSpec]] = {PUType.IMC: [], PUType.DPU: []}
    for p in pus:
        out[p.pu_type].append(p)
    return out


def schedulable_nodes(g: Graph) -> List[Node]:
    """All nodes that need a PU (drops free INPUT/OUTPUT glue)."""
    return [n for n in g.nodes.values() if not n.is_free()]
