"""Derived metrics and normalization helpers (paper §V).

The paper reports *Normalized Processing Rate* (measured rates divided by
their maximum across the compared configurations) and *Normalized
Latency* (latencies divided by their minimum).  These helpers normalize
collections of ``SimResult``s the same way.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping

from .simulator import SimResult


@dataclass
class NormalizedPoint:
    key: str                 # e.g. algorithm name or (alg, n_pus) label
    rate: float              # absolute frames/s
    latency: float           # absolute seconds
    norm_rate: float         # rate / max(rate over the group)
    norm_latency: float      # latency / min(latency over the group)
    mean_utilization: float


def normalize(group: Mapping[str, SimResult]) -> Dict[str, NormalizedPoint]:
    """Normalize a group of results per the paper's definition."""
    if not group:
        return {}
    max_rate = max(r.rate for r in group.values())
    min_lat = min(r.latency for r in group.values())
    out = {}
    for k, r in group.items():
        out[k] = NormalizedPoint(
            key=k,
            rate=r.rate,
            latency=r.latency,
            norm_rate=r.rate / max_rate if max_rate > 0 else 0.0,
            norm_latency=r.latency / min_lat if min_lat > 0 else 0.0,
            mean_utilization=r.mean_utilization,
        )
    return out


def utilization_table(result: SimResult) -> str:
    rows = ["pu_id  busy_s       utilization"]
    for pid in sorted(result.utilization):
        rows.append(
            f"{pid:<6d} {result.busy[pid]:<12.6f} {result.utilization[pid]*100:6.1f}%"
        )
    rows.append(f"mean utilization: {result.mean_utilization*100:.1f}%")
    return "\n".join(rows)
