"""Precompiled simulation contexts: the simulator's compiled core.

The discrete-event loop used to re-derive everything it needs from the
``Graph``/``CostModel`` objects on every call: predecessor/successor
dicts, per-node execution times (a ``CostModel.time`` call per event),
transfer costs, replica activity checks, and ``(stream, frame, node)``
tuple-keyed state dicts.  Profiling showed those lookups — not the heap
operations — dominating the loop.  A :class:`SimContext` hoists all of
it out of the hot path, once per (graph, cost model, stream structure):

* nodes renumbered to dense ``0..N-1`` indices in topological order,
* predecessor/successor adjacency as flat index tuples,
* bottom levels (the list-scheduling tiebreak) as a dense array,
* cross-PU transfer cost per producer node,
* replica round-robin activity precompiled per frame *phase*
  (``f % lcm(replica counts)``): per-phase missing-predecessor counts,
  initially-ready nodes, sink counts and active-successor lists,
* per-stream membership with the exact iteration orders the historical
  loop used (so event sequence numbers — and therefore results — are
  bit-identical).

Contexts are cached on the graph object itself (invalidated whenever
the graph mutates) and shared by every simulator instance built over
the same graph: the three measurement passes inside ``run()``, every
``lblp-r`` ``validate_rate`` probe, every ``ElasticSession`` event and
every benchmark sweep cell reuse one compiled structure.

Per-assignment state (which PU executes which node, at which speed) is
compiled separately into an :class:`ExecPlan` — per-node execution
times and per-edge transfer costs as dense arrays — and cached on the
context keyed by assignment identity, so repeated runs of the same
mapping (the common case) compile once.

Quantized time grid ("periodic" mode)
-------------------------------------
``ExecPlan`` can quantize all costs onto an integer picosecond grid
(held in floats, exact below 2**53).  On that grid the closed-loop
simulator state provably recurs — enabling the exact-match steady-state
early exit in ``simulator.py`` — at the price of ~1e-6 relative
rounding on reported times versus the default exact mode.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from .cost import CostModel
from .graph import Graph

#: quantized-mode time grid: 1 tick = 1 picosecond.  Integer-valued
#: floats stay exact under +/max up to 2**53 ticks (~2.5 hours of
#: simulated time), far beyond any benchmark horizon.
TIME_SCALE = 1e12

#: replica phase tables are precompiled when the lcm of all replica
#: counts is at most this; beyond it the loop falls back to computing
#: activity per injection (identical results, just slower).
MAX_PHASE_PERIOD = 64


def _phase_period(counts: Sequence[int]) -> int:
    out = 1
    for c in counts:
        if c > 1:
            out = out * c // math.gcd(out, c)
            if out > MAX_PHASE_PERIOD:
                return out
    return out


class ExecPlan:
    """Per-(context, assignment) compiled execution arrays."""

    __slots__ = ("pu_ids", "pu_index", "pu_of", "exec_t", "arrive", "quantized")

    def __init__(self, ctx: "SimContext", cm: CostModel, a, quantized: bool) -> None:
        g = ctx.graph
        self.quantized = quantized
        self.pu_ids: List[int] = [p.pu_id for p in a.pus]
        self.pu_index: Dict[int, int] = {pid: i for i, pid in enumerate(self.pu_ids)}
        specs = {p.pu_id: p for p in a.pus}

        # free nodes ride on any PU at zero cost; pin them to a successor's
        # (or predecessor's) PU so transfers are accounted sensibly — the
        # historical loop's rule, preserved verbatim (successors first,
        # earlier topo nodes pinned first, fleet head as last resort).
        pu_by_id = dict(a.mapping)
        for nid in ctx.ids:
            if nid not in pu_by_id:
                nbr = g.successors(nid) + g.predecessors(nid)
                pu_by_id[nid] = next(
                    (pu_by_id[m] for m in nbr if m in pu_by_id), a.pus[0].pu_id
                )
        self.pu_of: List[int] = [self.pu_index[pu_by_id[nid]] for nid in ctx.ids]

        # per-node execution times come from context-level tables keyed by
        # (pu_type, speed) — schedulers probing many candidate mappings
        # (lblp-x refine, lblp-r validation) rebuild plans often, and the
        # table lookup keeps that free of CostModel calls
        tables = {
            key: ctx.exec_table(spec.pu_type, spec.speed, quantized)
            for key, spec in specs.items()
        }
        pu_arr = [pu_by_id[nid] for nid in ctx.ids]
        self.exec_t: List[float] = [
            tables[pu_arr[j]][j] for j in range(ctx.n)
        ]

        # per phase, per node: (successor index, transfer cost) pairs for
        # the successors active at that phase (all of them when P == 1)
        xfer = ctx.xfer_table(quantized)
        pu_of = self.pu_of
        self.arrive: List[List[Tuple[Tuple[int, float], ...]]] = []
        for ph in range(len(ctx.succs_by_phase)):
            per_node = []
            for j in range(ctx.n):
                cost = xfer[j]
                per_node.append(
                    tuple(
                        (k, 0.0 if pu_of[k] == pu_of[j] else cost)
                        for k in ctx.succs_by_phase[ph][j]
                    )
                )
            self.arrive.append(per_node)


class SimContext:
    """Dense-index compiled view of one (graph, cost model, streams)."""

    def __init__(self, graph: Graph, cm: CostModel,
                 structure: Tuple[List[str], Dict[str, List[int]],
                                  Dict[str, List[int]], Dict[str, List[int]],
                                  Dict[int, str]]) -> None:
        self.graph = graph
        streams, members, sources, sinks, stream_of = structure
        order = graph.topo_order()
        self.n = len(order)
        self.ids: Tuple[int, ...] = tuple(order)
        self.idx: Dict[int, int] = {nid: j for j, nid in enumerate(order)}
        idx = self.idx
        self.preds: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(idx[p] for p in graph.predecessors(nid)) for nid in order
        )
        self.succs: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(idx[s] for s in graph.successors(nid)) for nid in order
        )
        self.free: Tuple[bool, ...] = tuple(
            graph.nodes[nid].is_free() for nid in order
        )

        # bottom levels over native execution times (the historical
        # `_bottom_levels`, bit-identical float computation)
        bl: Dict[int, float] = {}
        for nid in reversed(order):
            t = 0.0 if graph.nodes[nid].is_free() else cm.time(graph.nodes[nid])
            if math.isinf(t):
                t = 0.0
            succ = graph.successors(nid)
            bl[nid] = t + max((bl[s] for s in succ), default=0.0)
        self.blevel_by_id = bl
        self.negbl: Tuple[float, ...] = tuple(-bl[nid] for nid in order)

        self.xfer_cross: Tuple[float, ...] = tuple(
            cm.transfer(graph.nodes[nid], same_pu=False) for nid in order
        )

        # replica round-robin tags
        rep_cnt = [graph.nodes[nid].replica_count for nid in order]
        rep_idx = [graph.nodes[nid].meta.get("replica_index", 0) for nid in order]
        self.rep_cnt, self.rep_idx = tuple(rep_cnt), tuple(rep_idx)
        self.replicated = any(c > 1 for c in rep_cnt)
        period = _phase_period(rep_cnt) if self.replicated else 1
        self.phases_compiled = period <= MAX_PHASE_PERIOD
        self.phase_period = period if self.phases_compiled else 1

        # streams (dense)
        self.stream_keys: List[str] = list(streams)
        self.members: List[List[int]] = [
            [idx[nid] for nid in members[s]] for s in streams
        ]
        self.sources: List[List[int]] = [
            [idx[nid] for nid in sources[s]] for s in streams
        ]
        self.n_sinks: List[int] = [len(sinks[s]) for s in streams]
        self.stream_of: List[int] = [0] * self.n
        skey = {s: i for i, s in enumerate(streams)}
        for nid, s in stream_of.items():
            self.stream_of[idx[nid]] = skey[s]

        self._compile_phases()
        self._cm = cm
        self._plans: Dict[Tuple[int, bool], Tuple[object, ExecPlan]] = {}
        self._exec_tables: Dict[tuple, Tuple[float, ...]] = {}
        self._xfer_tables: Dict[bool, Tuple[float, ...]] = {}
        #: scratch memo for derived deterministic figures (e.g. the
        #: measured_rate cache in schedulers.lblp_r), keyed by content
        self.memo: Dict[tuple, object] = {}

    # -- cost tables ---------------------------------------------------------
    def exec_table(self, pu_type, speed: float,
                   quantized: bool) -> Tuple[float, ...]:
        """Per-node execution times on a (pu_type, speed) unit; free
        nodes cost 0.  Quantized tables live on the integer tick grid."""
        key = (pu_type, speed, quantized)
        tab = self._exec_tables.get(key)
        if tab is None:
            g, cm = self.graph, self._cm
            raw = [
                0.0 if g.nodes[nid].is_free()
                else cm.time(g.nodes[nid], pu_type, speed)
                for nid in self.ids
            ]
            if quantized:
                raw = [t if t == math.inf else float(round(t * TIME_SCALE))
                       for t in raw]
            tab = self._exec_tables[key] = tuple(raw)
        return tab

    def xfer_table(self, quantized: bool) -> Tuple[float, ...]:
        """Cross-PU transfer cost per producer node."""
        tab = self._xfer_tables.get(quantized)
        if tab is None:
            raw = self.xfer_cross
            if quantized:
                raw = tuple(t if t == math.inf else float(round(t * TIME_SCALE))
                            for t in raw)
            tab = self._xfer_tables[quantized] = tuple(raw)
        return tab

    # -- replica phase tables ---------------------------------------------
    def active(self, j: int, f: int) -> bool:
        c = self.rep_cnt[j]
        return c == 1 or f % c == self.rep_idx[j]

    def _compile_phases(self) -> None:
        """Per-phase activity tables (phase = frame % lcm of replica
        counts): active-successor lists, per-stream initial missing
        counts, initially-ready nodes and sink counts — everything the
        historical per-frame ``inject``/``finish`` recomputed."""
        P = self.phase_period
        if not self.phases_compiled:
            # dynamic fallback: single table with full successor lists;
            # the loop recomputes activity per injected frame instead
            self.succs_by_phase = [self.succs]
            self.base_missing = None
            self.init_ready = None
            self.phase_sinks = None
            return
        if not self.replicated:
            self.succs_by_phase = [self.succs]
            self.base_missing = [
                [[len(self.preds[j]) for j in range(self.n)]]
                for _ in self.stream_keys
            ]
            self.init_ready = [[list(src)] for src in self.sources]
            self.phase_sinks = [[c] for c in self.n_sinks]
            return
        self.succs_by_phase = [
            tuple(
                tuple(k for k in self.succs[j] if self.active(k, ph))
                for j in range(self.n)
            )
            for ph in range(P)
        ]
        self.base_missing = []
        self.init_ready = []
        self.phase_sinks = []
        for s, _ in enumerate(self.stream_keys):
            miss_by_phase, ready_by_phase, sinks_by_phase = [], [], []
            for ph in range(P):
                miss = [0] * self.n
                ready: List[int] = []
                sinks = 0
                # member order matters: the historical loop pushed the
                # "ready" events in this exact iteration order
                for j in self.members[s]:
                    if not self.active(j, ph):
                        continue
                    miss[j] = sum(1 for p in self.preds[j] if self.active(p, ph))
                    if not any(self.active(k, ph) for k in self.succs[j]):
                        sinks += 1
                    if miss[j] == 0:
                        ready.append(j)
                miss_by_phase.append(miss)
                ready_by_phase.append(ready)
                sinks_by_phase.append(sinks)
            self.base_missing.append(miss_by_phase)
            self.init_ready.append(ready_by_phase)
            self.phase_sinks.append(sinks_by_phase)

    # -- per-assignment plans ----------------------------------------------
    def plan(self, a, cm: CostModel, quantized: bool) -> ExecPlan:
        """Compiled execution arrays for ``a``; cached by identity so the
        passes of ``run()`` (and re-runs of a stored schedule) share one
        compilation."""
        key = (id(a), quantized)
        hit = self._plans.get(key)
        if hit is not None and hit[0] is a:
            return hit[1]
        if len(self._plans) >= 8:
            self._plans.clear()
        plan = ExecPlan(self, cm, a, quantized)
        self._plans[key] = (a, plan)
        return plan

    # -- cache -------------------------------------------------------------
    @staticmethod
    def for_graph(graph: Graph, cm: CostModel, kind: str,
                  structure_fn) -> "SimContext":
        """Fetch (or build) the context for ``graph`` under ``cm``.

        Cached on the graph object (cleared by ``Graph._invalidate`` on
        any mutation) keyed by the stream-structure kind and the cost
        model's calibration, so different hardware profiles and
        single-vs-multi-tenant views coexist."""
        cache: Optional[dict] = getattr(graph, "_sim_contexts", None)
        if cache is None:
            cache = graph._sim_contexts = {}
        key = (kind, type(cm), cm.profile)
        ctx = cache.get(key)
        if ctx is None:
            ctx = SimContext(graph, cm, structure_fn())
            cache[key] = ctx
        return ctx
