"""Precompiled simulation contexts: the simulator's compiled core.

The discrete-event loop used to re-derive everything it needs from the
``Graph``/``CostModel`` objects on every call: predecessor/successor
dicts, per-node execution times (a ``CostModel.time`` call per event),
transfer costs, replica activity checks, and ``(stream, frame, node)``
tuple-keyed state dicts.  Profiling showed those lookups — not the heap
operations — dominating the loop.  A :class:`SimContext` hoists all of
it out of the hot path, once per (graph, cost model, stream structure):

* nodes renumbered to dense ``0..N-1`` indices in topological order,
* predecessor/successor adjacency as flat index tuples,
* bottom levels (the list-scheduling tiebreak) as a dense array,
* cross-PU transfer cost per producer node,
* replica round-robin activity precompiled per frame *phase*
  (``f % lcm(replica counts)``): per-phase missing-predecessor counts,
  initially-ready nodes, sink counts and active-successor lists,
* per-stream membership with the exact iteration orders the historical
  loop used (so event sequence numbers — and therefore results — are
  bit-identical).

Contexts are cached on the graph object itself (invalidated whenever
the graph mutates) and shared by every simulator instance built over
the same graph: the three measurement passes inside ``run()``, every
``lblp-r`` ``validate_rate`` probe, every ``ElasticSession`` event and
every benchmark sweep cell reuse one compiled structure.

Per-assignment state (which PU executes which node, at which speed) is
compiled separately into an :class:`ExecPlan` — per-node execution
times and per-edge transfer costs as dense arrays — and cached on the
context keyed by assignment identity, so repeated runs of the same
mapping (the common case) compile once.

Seeded (delta) builds for replica variants
------------------------------------------
``lblp-r`` probes dozens of replica variants of one base graph; each
variant is a fresh ``Graph`` object, so a from-scratch context build per
candidate would repeat the expensive parts verbatim.  Graphs derived by
replica-preserving transforms (``copy``/``replicate``/``drop_replica``,
and their composition ``with_replicas``) carry a link to their pristine
ancestor (``Graph.ctx_seed``); when that ancestor already has a context
under the same cache key, the variant's context is *seeded* from it:
bottom levels and execution/transfer cost tables are copied row-wise
(replica clones map onto their ``replica_group`` base row) instead of
recomputed — provably bit-identical, because those transforms change
neither any surviving node's cost nor its bottom level.  Replica phase
tables can't be copied (the phase period itself changes), but they are
*delta-built*: only nodes whose activity, predecessor counts or
successor lists actually vary across phases (replicas and their
neighbours) are recomputed per phase; everything else patches in from
phase-invariant base rows, and ``ExecPlan`` arrival rows alias one
tuple across all phases where the active-successor list is unchanged.

Quantized time grid ("periodic" mode)
-------------------------------------
``ExecPlan`` can quantize all costs onto an integer picosecond grid
(held in floats, exact below 2**53).  On that grid the closed-loop
simulator state provably recurs — enabling the exact-match steady-state
early exit in ``simulator.py`` — at the price of ~1e-6 relative
rounding on reported times versus the default exact mode.  For
multi-stream runs the fair-queueing virtual-time weights are quantized
too (:func:`quantize_stream_weights`): each stream's weight becomes an
integer whose ratios are small rationals, so virtual-time arithmetic is
exact and the joint state can recur at synchronized per-stream frame
shifts (see the simulator's module docstring).

The per-slot missing-predecessor vectors are additionally mirrored into
integer *digests* (base-B positional encoding with ``B`` > max
indegree, one big-int per slot, O(1) to update per arrival): digest
equality is exactly vector equality, which lets the steady-state
fingerprints compare slot progress without materializing an N-tuple per
slot per completion.
"""

from __future__ import annotations

import math
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

from .cost import CostModel
from .graph import Graph

#: quantized-mode time grid: 1 tick = 1 picosecond.  Integer-valued
#: floats stay exact under +/max up to 2**53 ticks (~2.5 hours of
#: simulated time), far beyond any benchmark horizon.
TIME_SCALE = 1e12

#: replica phase tables are precompiled when the lcm of all replica
#: counts is at most this; beyond it the loop falls back to computing
#: activity per injection (identical results, just slower).
MAX_PHASE_PERIOD = 64

#: single bound for the shared per-context ``memo`` dict (measured_rate
#: probes, run() results, ...): every writer evicts LRU-style to this
MEMO_CAP = 512

#: max denominator when rationalizing multi-stream fair-queueing weight
#: ratios for the quantized grid.  Small denominators keep the joint
#: steady-state period short (the per-stream frame shifts of one period
#: are the rationalized numerators/denominators), at a worst-case
#: ~1/(2*16) ~ 3% perturbation of the weight *ratios* — comparable to
#: scheduling noise and far below the 5% tolerance the property tests
#: grant periodic mode.  Typical CNN mixes land well under 1%.
VT_MAX_DENOM = 16

#: give up on integer virtual-time weights (and therefore multi-stream
#: steady-state detection) when ``weight * frames`` could leave the
#: exact-float integer range.
_VT_MAX_SAFE = 2.0**52


def _phase_period(counts: Sequence[int]) -> int:
    out = 1
    for c in counts:
        if c > 1:
            out = out * c // math.gcd(out, c)
            if out > MAX_PHASE_PERIOD:
                return out
    return out


def quantize_stream_weights(weights: Sequence[float],
                            max_frames: int,
                            max_denom: int = VT_MAX_DENOM,
                            ) -> Optional[List[float]]:
    """Integer-valued virtual-time weights with small-rational ratios.

    Each weight ratio ``w_s / min(w)`` is replaced by its best rational
    approximation with denominator <= ``max_denom`` and all weights are
    rescaled onto the common denominator, so every weight is an exact
    integer (held in a float).  On these weights all virtual-time
    comparisons (``frame * weight``) are exact integer arithmetic below
    2**53, which makes the fair-queueing interleave *frame-shift
    invariant*: shifting every stream ``s`` by ``dF_s`` frames with
    ``dF_s * W_s`` equal across streams preserves every comparison —
    the property the multi-stream steady-state fingerprints rely on.

    Returns ``None`` when the integer weights could overflow the exact
    range for the requested frame budget (callers then keep the float
    weights and skip steady-state detection).
    """
    wmin = min(weights)
    if wmin <= 0:
        return None
    fracs = [Fraction(w / wmin).limit_denominator(max_denom) for w in weights]
    denom_lcm = 1
    for f in fracs:
        denom_lcm = denom_lcm * f.denominator // math.gcd(denom_lcm, f.denominator)
    ws = [f.numerator * (denom_lcm // f.denominator) for f in fracs]
    if max(ws) * max(max_frames, 1) > _VT_MAX_SAFE:
        return None
    return [float(w) for w in ws]


class ExecPlan:
    """Per-(context, assignment) compiled execution arrays."""

    __slots__ = ("pu_ids", "pu_index", "pu_of", "exec_t", "arrive", "quantized")

    def __init__(self, ctx: "SimContext", cm: CostModel, a, quantized: bool) -> None:
        g = ctx.graph
        self.quantized = quantized
        self.pu_ids: List[int] = [p.pu_id for p in a.pus]
        self.pu_index: Dict[int, int] = {pid: i for i, pid in enumerate(self.pu_ids)}
        specs = {p.pu_id: p for p in a.pus}

        # free nodes ride on any PU at zero cost; pin them to a successor's
        # (or predecessor's) PU so transfers are accounted sensibly — the
        # historical loop's rule, preserved verbatim (successors first,
        # earlier topo nodes pinned first, fleet head as last resort).
        pu_by_id = dict(a.mapping)
        for nid in ctx.ids:
            if nid not in pu_by_id:
                nbr = g.successors(nid) + g.predecessors(nid)
                pu_by_id[nid] = next(
                    (pu_by_id[m] for m in nbr if m in pu_by_id), a.pus[0].pu_id
                )
        self.pu_of: List[int] = [self.pu_index[pu_by_id[nid]] for nid in ctx.ids]

        # per-node execution times come from context-level tables keyed by
        # (pu_type, speed) — schedulers probing many candidate mappings
        # (lblp-x refine, lblp-r validation) rebuild plans often, and the
        # table lookup keeps that free of CostModel calls
        tables = {
            key: ctx.exec_table(spec.pu_type, spec.speed, quantized)
            for key, spec in specs.items()
        }
        pu_arr = [pu_by_id[nid] for nid in ctx.ids]
        self.exec_t: List[float] = [
            tables[pu_arr[j]][j] for j in range(ctx.n)
        ]

        # per phase, per node: (successor index, transfer cost) pairs for
        # the successors active at that phase (all of them when P == 1).
        # Nodes whose active-successor list is phase-invariant (the vast
        # majority under replication) share one row tuple across phases.
        xfer = ctx.xfer_table(quantized)
        pu_of = self.pu_of
        self.arrive: List[List[Tuple[Tuple[int, float], ...]]] = []
        n_phases = len(ctx.succs_by_phase)
        row_cache: List[Tuple[tuple, tuple]] = [None] * ctx.n  # (succs, row)
        for ph in range(n_phases):
            per_node = []
            succs_ph = ctx.succs_by_phase[ph]
            for j in range(ctx.n):
                succ = succs_ph[j]
                hit = row_cache[j]
                if hit is not None and hit[0] is succ:
                    per_node.append(hit[1])
                    continue
                cost = xfer[j]
                row = tuple(
                    (k, 0.0 if pu_of[k] == pu_of[j] else cost)
                    for k in succ
                )
                row_cache[j] = (succ, row)
                per_node.append(row)
            self.arrive.append(per_node)


class SimContext:
    """Dense-index compiled view of one (graph, cost model, streams)."""

    def __init__(self, graph: Graph, cm: CostModel,
                 structure: Tuple[List[str], Dict[str, List[int]],
                                  Dict[str, List[int]], Dict[str, List[int]],
                                  Dict[int, str]],
                 seed: Optional["SimContext"] = None) -> None:
        self.graph = graph
        streams, members, sources, sinks, stream_of = structure
        order = graph.topo_order()
        self.n = len(order)
        self.ids: Tuple[int, ...] = tuple(order)
        self.idx: Dict[int, int] = {nid: j for j, nid in enumerate(order)}
        idx = self.idx
        self.preds: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(idx[p] for p in graph.predecessors(nid)) for nid in order
        )
        self.succs: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(idx[s] for s in graph.successors(nid)) for nid in order
        )
        self.free: Tuple[bool, ...] = tuple(
            graph.nodes[nid].is_free() for nid in order
        )

        # map every node onto the seed context's dense index (replica
        # clones onto their replica_group base); a node the seed cannot
        # account for voids the whole seed (defensive: only
        # replica-preserving derivations set Graph._ctx_seed).
        self._seed = None
        self._seed_src: Optional[List[int]] = None
        if seed is not None:
            src = []
            for nid in order:
                base = nid if nid in seed.idx else \
                    graph.nodes[nid].meta.get("replica_group")
                if base is None or base not in seed.idx:
                    src = None
                    break
                src.append(seed.idx[base])
            if src is not None:
                self._seed = seed
                self._seed_src = src

        # bottom levels over native execution times (the historical
        # `_bottom_levels`, bit-identical float computation).  Seeded
        # builds copy the ancestor's values: a replica clone's bottom
        # level equals its base node's (same cost, same successors), and
        # no other node's changes (replicas never alter the max over a
        # predecessor's successor levels — the clone ties its base).
        if self._seed is not None:
            sbl = self._seed.blevel_by_id
            bl = {nid: sbl[nid if nid in sbl
                           else graph.nodes[nid].meta["replica_group"]]
                  for nid in order}
        else:
            bl = {}
            for nid in reversed(order):
                t = 0.0 if graph.nodes[nid].is_free() else cm.time(graph.nodes[nid])
                if math.isinf(t):
                    t = 0.0
                succ = graph.successors(nid)
                bl[nid] = t + max((bl[s] for s in succ), default=0.0)
        self.blevel_by_id = bl
        self.negbl: Tuple[float, ...] = tuple(-bl[nid] for nid in order)

        if self._seed is not None:
            sx = self._seed.xfer_cross
            self.xfer_cross = tuple(sx[s] for s in self._seed_src)
        else:
            self.xfer_cross = tuple(
                cm.transfer(graph.nodes[nid], same_pu=False) for nid in order
            )

        # replica round-robin tags
        rep_cnt = [graph.nodes[nid].replica_count for nid in order]
        rep_idx = [graph.nodes[nid].meta.get("replica_index", 0) for nid in order]
        self.rep_cnt, self.rep_idx = tuple(rep_cnt), tuple(rep_idx)
        self.replicated = any(c > 1 for c in rep_cnt)
        period = _phase_period(rep_cnt) if self.replicated else 1
        self.phases_compiled = period <= MAX_PHASE_PERIOD
        self.phase_period = period if self.phases_compiled else 1

        # streams (dense)
        self.stream_keys: List[str] = list(streams)
        self.members: List[List[int]] = [
            [idx[nid] for nid in members[s]] for s in streams
        ]
        self.sources: List[List[int]] = [
            [idx[nid] for nid in sources[s]] for s in streams
        ]
        self.n_sinks: List[int] = [len(sinks[s]) for s in streams]
        self.stream_of: List[int] = [0] * self.n
        skey = {s: i for i, s in enumerate(streams)}
        for nid, s in stream_of.items():
            self.stream_of[idx[nid]] = skey[s]

        # positional weights of the missing-vector digests: base-B with
        # B > max indegree, so digest equality <=> vector equality
        B = max((len(p) for p in self.preds), default=1) + 1
        pw = [1] * self.n
        for j in range(1, self.n):
            pw[j] = pw[j - 1] * B
        self.digest_pow: List[int] = pw

        self._compile_phases()
        self._cm = cm
        self._plans: Dict[Tuple[int, bool], Tuple[object, ExecPlan]] = {}
        self._exec_tables: Dict[tuple, Tuple[float, ...]] = {}
        self._xfer_tables: Dict[bool, Tuple[float, ...]] = {}
        #: scratch memo for derived deterministic figures (e.g. the
        #: measured_rate cache in schedulers.lblp_r), keyed by content
        self.memo: Dict[tuple, object] = {}

    # -- cost tables ---------------------------------------------------------
    def exec_table(self, pu_type, speed: float,
                   quantized: bool) -> Tuple[float, ...]:
        """Per-node execution times on a (pu_type, speed) unit; free
        nodes cost 0.  Quantized tables live on the integer tick grid.
        Seeded contexts copy the ancestor's rows instead of re-pricing."""
        key = (pu_type, speed, quantized)
        tab = self._exec_tables.get(key)
        if tab is None:
            if self._seed is not None:
                srow = self._seed.exec_table(pu_type, speed, quantized)
                tab = tuple(srow[s] for s in self._seed_src)
            else:
                g, cm = self.graph, self._cm
                raw = [
                    0.0 if g.nodes[nid].is_free()
                    else cm.time(g.nodes[nid], pu_type, speed)
                    for nid in self.ids
                ]
                if quantized:
                    raw = [t if t == math.inf else float(round(t * TIME_SCALE))
                           for t in raw]
                tab = tuple(raw)
            self._exec_tables[key] = tab
        return tab

    def xfer_table(self, quantized: bool) -> Tuple[float, ...]:
        """Cross-PU transfer cost per producer node."""
        tab = self._xfer_tables.get(quantized)
        if tab is None:
            raw = self.xfer_cross
            if quantized:
                raw = tuple(t if t == math.inf else float(round(t * TIME_SCALE))
                            for t in raw)
            tab = self._xfer_tables[quantized] = tuple(raw)
        return tab

    # -- replica phase tables ---------------------------------------------
    def active(self, j: int, f: int) -> bool:
        c = self.rep_cnt[j]
        return c == 1 or f % c == self.rep_idx[j]

    def _compile_phases(self) -> None:
        """Per-phase activity tables (phase = frame % lcm of replica
        counts): active-successor lists, per-stream initial missing
        counts, initially-ready nodes and sink counts — everything the
        historical per-frame ``inject``/``finish`` recomputed.

        Delta-built: only nodes whose activity, missing count, sink-ness
        or active-successor list actually varies with the phase (replicas
        and their graph neighbours) are recomputed per phase; the rest is
        patched in from phase-invariant base rows.  Content is identical
        to the straightforward per-phase recomputation (pinned by the
        property tests)."""
        P = self.phase_period
        if not self.phases_compiled:
            # dynamic fallback: single table with full successor lists;
            # the loop recomputes activity per injected frame instead
            self.succs_by_phase = [self.succs]
            self.base_missing = None
            self.init_ready = None
            self.phase_sinks = None
            self.base_digest = None
            return
        pw = self.digest_pow
        if not self.replicated:
            self.succs_by_phase = [self.succs]
            self.base_missing = [
                [[len(self.preds[j]) for j in range(self.n)]]
                for _ in self.stream_keys
            ]
            self.init_ready = [[list(src)] for src in self.sources]
            self.phase_sinks = [[c] for c in self.n_sinks]
            self.base_digest = [
                [sum(row[j] * pw[j] for j in range(self.n))]
                for row in (bm[0] for bm in self.base_missing)
            ]
            return

        rep = [self.rep_cnt[j] > 1 for j in range(self.n)]
        # phase-varying per aspect: own activity / missing count / succs
        var_act = rep
        var_miss = [rep[j] or any(rep[p] for p in self.preds[j])
                    for j in range(self.n)]
        var_succ = [any(rep[k] for k in self.succs[j]) for j in range(self.n)]

        self.succs_by_phase = []
        for ph in range(P):
            row = list(self.succs)
            for j in range(self.n):
                if var_succ[j]:
                    row[j] = tuple(k for k in self.succs[j]
                                   if self.active(k, ph))
            self.succs_by_phase.append(tuple(row))

        self.base_missing = []
        self.init_ready = []
        self.phase_sinks = []
        self.base_digest = []
        for s, _ in enumerate(self.stream_keys):
            mem = self.members[s]
            # phase-invariant member aspects
            stat_miss = [0] * self.n
            dyn_members = []          # members needing per-phase treatment
            stat_sinks = 0
            stat_ready = set()
            for j in mem:
                if var_act[j] or var_miss[j] or var_succ[j]:
                    dyn_members.append(j)
                    continue
                stat_miss[j] = len(self.preds[j])
                if not self.succs[j]:
                    stat_sinks += 1
                if not self.preds[j]:
                    stat_ready.add(j)
            base_row = stat_miss
            base_dig = sum(base_row[j] * pw[j] for j in mem)
            miss_by_phase, ready_by_phase = [], []
            sinks_by_phase, dig_by_phase = [], []
            for ph in range(P):
                miss = base_row[:]
                dig = base_dig
                sinks = stat_sinks
                dyn_ready = set()
                for j in dyn_members:
                    if not self.active(j, ph):
                        continue
                    m = sum(1 for p in self.preds[j] if self.active(p, ph))
                    miss[j] = m
                    dig += m * pw[j]
                    if not any(self.active(k, ph) for k in self.succs[j]):
                        sinks += 1
                    if m == 0:
                        dyn_ready.add(j)
                # member order matters: the historical loop pushed the
                # "ready" events in this exact iteration order
                ready = [j for j in mem
                         if j in stat_ready or j in dyn_ready]
                miss_by_phase.append(miss)
                ready_by_phase.append(ready)
                sinks_by_phase.append(sinks)
                dig_by_phase.append(dig)
            self.base_missing.append(miss_by_phase)
            self.init_ready.append(ready_by_phase)
            self.phase_sinks.append(sinks_by_phase)
            self.base_digest.append(dig_by_phase)

    # -- per-assignment plans ----------------------------------------------
    def plan(self, a, cm: CostModel, quantized: bool) -> ExecPlan:
        """Compiled execution arrays for ``a``; cached by identity so the
        passes of ``run()`` (and re-runs of a stored schedule) share one
        compilation."""
        key = (id(a), quantized)
        hit = self._plans.get(key)
        if hit is not None and hit[0] is a:
            return hit[1]
        if len(self._plans) >= 8:
            self._plans.clear()
        plan = ExecPlan(self, cm, a, quantized)
        self._plans[key] = (a, plan)
        return plan

    # -- cache -------------------------------------------------------------
    @staticmethod
    def for_graph(graph: Graph, cm: CostModel, kind: str,
                  structure_fn) -> "SimContext":
        """Fetch (or build) the context for ``graph`` under ``cm``.

        Cached on the graph object (cleared by ``Graph._invalidate`` on
        any mutation) keyed by the stream-structure kind and the cost
        model's calibration, so different hardware profiles and
        single-vs-multi-tenant views coexist.  A graph derived by a
        replica-preserving transform seeds its context from the
        ancestor's (same cache key) when one exists."""
        cache: Optional[dict] = getattr(graph, "_sim_contexts", None)
        if cache is None:
            cache = graph._sim_contexts = {}
        key = (kind, type(cm), cm.profile)
        ctx = cache.get(key)
        if ctx is None:
            seed = None
            seed_graph = graph.ctx_seed()
            if seed_graph is not None:
                seed = getattr(seed_graph, "_sim_contexts", {}).get(key)
            ctx = SimContext(graph, cm, structure_fn(), seed=seed)
            cache[key] = ctx
        return ctx
