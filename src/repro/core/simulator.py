"""Discrete-event simulator of the IMCE's pipelined compute-and-forward
execution (paper §III/§V).

Model
-----
* Frames (inference requests) stream in; several frames are in flight at
  once, bounded by ``max_in_flight`` (the IMCE PUs "run multiple DNN nodes
  concurrently" with finite DRAM buffering).
* A node instance (frame f, node n) becomes *ready* once every predecessor
  instance has finished AND its output has been forwarded to n's PU
  (transfer over shared DRAM + IPI; zero if producer shares the PU).
* Every PU executes one node at a time (exclusive); among ready instances
  it picks the lowest frame first, then the highest bottom-level (classic
  critical-path list-scheduling tiebreak), then node id.  Transfers are
  DMA — they do not occupy the PU.
* Fused activations cost nothing (inside the PU datapath), matching the
  IMCE.

One event loop
--------------
There is exactly one event-loop implementation, ``_run_streams``: it
executes any number of *frame streams* over the graph.  A plain
single-model run is the 1-stream special case (``IMCESimulator``); a
multi-tenant union drives one stream per tenant
(``MultiTenantSimulator``).  The subclasses differ only in the
``_stream_view`` they hand the loop and in how ``run`` aggregates the
results — the ready-queue order for one stream is provably identical to
the historical single-tenant simulator (the stream's virtual-time key
``f * weight`` is strictly monotone in ``f`` for a constant weight), and
``tests/test_sim_equivalence.py`` pins bit-identical results on the
paper-validation graphs.

Layer replication (LRMP-style)
------------------------------
Nodes cloned by ``Graph.replicate(node_id, k)`` carry
``replica_index``/``replica_count`` tags; the loop routes frame ``f`` to
replica ``f % k`` (round-robin split) and consumers merge transparently —
an inactive replica simply does not exist for that frame.  The analytic
bound uses the amortized per-frame load (``CostModel.frame_time``).

Measurements
------------
* ``latency``   — the paper's latency metric: mean frame *sojourn* time
  (completion - injection) in double-buffered streaming (``in_flight=2``,
  capture/process overlap, the standard camera-pipeline operating point).
  This reproduces the paper's latency behaviour: it decreases with #PUs
  (queueing shrinks) and converges across algorithms when every node has
  its own PU.  An isolated single-frame makespan is also reported
  (``latency_isolated``); on mostly-sequential CNNs it is
  mapping-invariant up to transfer costs, which is why the streaming
  sojourn must be the figure-of-merit (see EXPERIMENTS.md).
* ``interval``  — steady-state time between consecutive frame completions
  at saturation (deep pipelining); processing rate is ``1/interval``.
* ``utilization`` — per-PU busy fraction over the steady-state window
  (paper Table I).

The analytic pipeline bound ``interval >= max_pu(amortized busy per
frame)`` is asserted (within epsilon) in tests; LBLP's load balancing
minimizes exactly that bound, and LBLP-R lowers it further by
replicating the bottleneck node.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .cost import CostModel
from .graph import Graph, MultiTenantGraph
from .schedulers.base import Assignment


@dataclass
class TenantMetrics:
    """Steady-state figures of one tenant's frame stream (multi-tenant runs)."""

    tenant: str
    frames: int                         # completed frames
    rate: float                         # tenant frames/s at steady state
    interval: float                     # steady-state per-frame interval [s]
    latency: float                      # mean steady-state sojourn [s]
    bound_interval: float               # tenant's own max per-PU load bound
    busy: Dict[int, float]              # pu_id -> busy seconds for this tenant
    utilization_share: float            # tenant busy / fleet busy (whole run)
    injected_rate: Optional[float] = None  # requested open-loop rate, if any


@dataclass
class SimResult:
    latency: float                      # streaming sojourn latency [s]
    latency_isolated: float             # single-frame makespan [s]
    interval: float                     # steady-state per-frame interval [s]
    rate: float                         # 1/interval [frames/s]
    makespan: float                     # full streaming-run span [s]
    frames: int
    busy: Dict[int, float]              # pu_id -> busy seconds (whole run)
    utilization: Dict[int, float]       # pu_id -> busy fraction, steady window
    mean_utilization: float
    per_frame_busy: Dict[int, float]    # pu_id -> amortized busy s per frame
    bound_interval: float               # analytic max-load bound
    meta: dict = field(default_factory=dict)
    tenants: Dict[str, TenantMetrics] = field(default_factory=dict)


@dataclass
class _StreamView:
    """How the event loop sees the graph's frame streams.

    ``IMCESimulator`` exposes one stream spanning the whole graph;
    ``MultiTenantSimulator`` exposes one per tenant.  ``weight`` is the
    stream's virtual-time increment per frame (start-time fair queueing);
    for a single stream any positive constant yields the historical
    frame-number ordering.
    """

    streams: List[str]
    nodes: Dict[str, List[int]]         # stream -> member node ids
    sources: Dict[str, List[int]]       # stream -> source node ids
    sinks: Dict[str, List[int]]         # stream -> sink node ids
    stream_of: Dict[int, str]           # node id -> stream
    weight: Dict[str, float]            # stream -> virtual-time weight


class IMCESimulator:
    """Event-driven executor of an ``Assignment`` over a ``Graph``."""

    def __init__(self, graph: Graph, cost_model: Optional[CostModel] = None,
                 max_in_flight: int = 0) -> None:
        self.g = graph
        self.cm = cost_model or CostModel()
        self.max_in_flight = max_in_flight  # 0 -> auto (=|PUs|+2)
        # bottom levels for the list-scheduling tiebreak
        self._blevel = self._bottom_levels()

    def _bottom_levels(self) -> Dict[int, float]:
        bl: Dict[int, float] = {}
        for nid in reversed(self.g.topo_order()):
            t = self.cm.time(self.g.nodes[nid]) if not self.g.nodes[nid].is_free() else 0.0
            if math.isinf(t):
                t = 0.0
            succ = self.g.successors(nid)
            bl[nid] = t + max((bl[s] for s in succ), default=0.0)
        return bl

    # -- public API -----------------------------------------------------------
    def run(self, assignment: Assignment, frames: int = 64) -> SimResult:
        """Full evaluation: isolated latency run + double-buffered latency
        run + saturated streaming throughput run."""
        isolated, _, _, _ = self._simulate(assignment, frames=1, in_flight=1)
        # double-buffered sojourn latency (the paper's latency metric)
        _, _, _, sojourns = self._simulate(
            assignment, frames=max(frames // 2, 16), in_flight=2
        )
        k = len(sojourns) // 4
        steady = sojourns[k:] or sojourns
        latency = sum(steady) / len(steady)
        in_flight = self.max_in_flight or (len(assignment.pus) + 2)
        makespan, completions, busy, _ = self._simulate(
            assignment, frames=frames, in_flight=in_flight
        )
        interval, util_window = self._steady_state(completions)
        busy_window = self._busy_in_window(busy, *util_window)
        window_span = max(util_window[1] - util_window[0], 1e-18)
        utilization = {p: b / window_span for p, b in busy_window.items()}
        per_frame_busy = self._per_frame_busy(assignment)
        bound = max(per_frame_busy.values()) if per_frame_busy else 0.0
        total_busy = {p: sum(iv[1] - iv[0] for iv in ivs) for p, ivs in busy.items()}
        return SimResult(
            latency=latency,
            latency_isolated=isolated,
            interval=interval,
            rate=1.0 / interval if interval > 0 else math.inf,
            makespan=makespan,
            frames=frames,
            busy=total_busy,
            utilization=utilization,
            mean_utilization=sum(utilization.values()) / max(len(utilization), 1),
            per_frame_busy=per_frame_busy,
            bound_interval=bound,
            meta={"algorithm": assignment.algorithm, "in_flight": in_flight},
        )

    def latency_only(self, assignment: Assignment) -> float:
        """Isolated single-frame makespan."""
        latency, _, _, _ = self._simulate(assignment, frames=1, in_flight=1)
        return latency

    # -- stream view ----------------------------------------------------------
    def _stream_view(self, a: Assignment) -> _StreamView:
        """One stream spanning the whole graph (single-model serving)."""
        g = self.g
        key = g.name
        order = g.topo_order()
        return _StreamView(
            streams=[key],
            nodes={key: order},
            sources={key: g.sources()},
            sinks={key: g.sinks()},
            stream_of={n: key for n in order},
            weight={key: 1.0},  # one stream: any constant == frame order
        )

    # -- internals -----------------------------------------------------------
    def _per_frame_busy(self, a: Assignment) -> Dict[int, float]:
        out = {p.pu_id: 0.0 for p in a.pus}
        for nid, pid in a.mapping.items():
            pu = a.pu_by_id(pid)
            out[pid] += self.cm.frame_time(self.g.nodes[nid], pu.pu_type, pu.speed)
        return out

    def _simulate(self, a: Assignment, frames: int, in_flight: int,
                  ) -> Tuple[float, List[float],
                             Dict[int, List[Tuple[float, float]]], List[float]]:
        """Single-stream adapter over the shared event loop (kept for the
        historical return shape: makespan, completions, busy, sojourns).
        On a multi-stream view every stream gets ``frames`` and the first
        stream's completions/sojourns are reported."""
        makespan, completions, busy_iv, sojourns, _ = self._run_streams(
            a, frames=frames, in_flight=in_flight)
        first = next(iter(completions))
        return makespan, completions[first], busy_iv, sojourns[first]

    def _run_streams(
        self, a: Assignment, frames, in_flight: int,
        rates: Optional[Dict[str, float]] = None,
    ) -> Tuple[float, Dict[str, List[float]],
               Dict[int, List[Tuple[float, float]]],
               Dict[str, List[float]], Dict[str, Dict[int, float]]]:
        """THE event loop: stream-keyed frames over one graph.

        A frame instance is ``(stream, f)`` and only traverses the
        stream's member nodes; replicated nodes additionally serve only
        the frames of their round-robin slot.  Two injection regimes:
        closed-loop (bounded in-flight, re-inject on completion) and
        open-loop (``rates``: frame f injected at ``f / rate``).

        ``frames`` is a per-stream dict, or an int applied to every
        stream of the view.  Returns ``(makespan, completions-by-stream,
        busy intervals per PU, sojourns-by-stream,
        busy-by-stream-by-PU)``.
        """
        g, cm = self.g, self.cm
        view = self._stream_view(a)
        if isinstance(frames, int):
            frames = {s: frames for s in view.streams}
        order = g.topo_order()
        preds = {n: g.predecessors(n) for n in order}
        succs = {n: g.successors(n) for n in order}
        streams = view.streams

        pu_of = dict(a.mapping)
        # free nodes ride on any PU at zero cost; pin them to a successor's
        # (or predecessor's) PU so transfers are accounted sensibly.
        for nid in order:
            if nid not in pu_of:
                nbr = succs[nid] + preds[nid]
                pu_of[nid] = next(
                    (pu_of[m] for m in nbr if m in pu_of), a.pus[0].pu_id
                )
        speed = {p.pu_id: p for p in a.pus}

        # round-robin replica routing: replica i of a k-group exists only
        # for the frames with f % k == i (Graph.replicate)
        rep_cnt = {n: g.nodes[n].replica_count for n in order}
        rep_idx = {n: g.nodes[n].meta.get("replica_index", 0) for n in order}
        replicated = any(c > 1 for c in rep_cnt.values())

        def active(nid: int, f: int) -> bool:
            c = rep_cnt[nid]
            return c == 1 or f % c == rep_idx[nid]

        def exec_time(nid: int) -> float:
            node = g.nodes[nid]
            if node.is_free():
                return 0.0
            pu = speed[pu_of[nid]]
            return cm.time(node, pu.pu_type, pu.speed)

        # state
        evq: List[Tuple[float, int, str, tuple]] = []
        seq = 0

        def push(t: float, kind: str, payload: tuple) -> None:
            nonlocal seq
            heapq.heappush(evq, (t, seq, kind, payload))
            seq += 1

        missing: Dict[Tuple[str, int, int], int] = {}   # (stream, f, node)
        inject_time: Dict[Tuple[str, int], float] = {}
        complete_time: Dict[Tuple[str, int], float] = {}
        frame_left: Dict[Tuple[str, int], int] = {}
        injected = {s: 0 for s in streams}
        n_sinks = {s: len(view.sinks[s]) for s in streams}
        ready_q: Dict[int, List[Tuple[float, int, float, int, float]]] = {
            p.pu_id: [] for p in a.pus
        }
        pu_free_at: Dict[int, float] = {p.pu_id: 0.0 for p in a.pus}
        pu_idle: Dict[int, bool] = {p.pu_id: True for p in a.pus}
        busy_iv: Dict[int, List[Tuple[float, float]]] = {p.pu_id: [] for p in a.pus}
        stream_busy: Dict[str, Dict[int, float]] = {
            s: {p.pu_id: 0.0 for p in a.pus} for s in streams
        }
        completions: Dict[str, List[float]] = {s: [] for s in streams}

        def inject(sn: str, f: int, t: float) -> None:
            inject_time[(sn, f)] = t
            if not replicated:
                frame_left[(sn, f)] = n_sinks[sn]
                for nid in view.nodes[sn]:
                    missing[(sn, f, nid)] = len(preds[nid])
                for nid in view.sources[sn]:
                    push(t, "ready", (sn, f, nid))
            else:
                # per-frame view: inactive replicas do not exist for f
                sinks = 0
                for nid in view.nodes[sn]:
                    if not active(nid, f):
                        continue
                    missing[(sn, f, nid)] = sum(
                        1 for p in preds[nid] if active(p, f))
                    if not any(active(s, f) for s in succs[nid]):
                        sinks += 1
                    if missing[(sn, f, nid)] == 0:
                        push(t, "ready", (sn, f, nid))
                frame_left[(sn, f)] = sinks
            injected[sn] += 1

        def enqueue_ready(sn: str, f: int, nid: int, t: float) -> None:
            pid = pu_of[nid]
            # virtual time first (cross-stream fairness), then per-stream
            # frame number and the critical-path tiebreak; for a single
            # stream this is exactly the historical (f, -blevel, nid) order.
            heapq.heappush(
                ready_q[pid],
                (f * view.weight[sn], f, -self._blevel[nid], nid, t))
            if pu_idle[pid]:
                push(max(t, pu_free_at[pid]), "dispatch", (pid,))

        def finish(sn: str, f: int, nid: int, t: float) -> None:
            """Outputs of (stream, f, nid) forward to successors."""
            node = g.nodes[nid]
            outs = succs[nid]
            if replicated:
                outs = [s for s in outs if active(s, f)]
            if not outs:
                frame_left[(sn, f)] -= 1
                if frame_left[(sn, f)] == 0:
                    completions[sn].append(t)
                    complete_time[(sn, f)] = t
                    push(t, "complete", (sn, f))
                return
            for s in outs:
                xfer = cm.transfer(node, same_pu=(pu_of[s] == pu_of[nid]))
                push(t + xfer, "arrive", (sn, f, s))

        # prime / schedule injections
        if rates is not None:
            for sn in streams:
                r = rates[sn]
                if r <= 0:
                    raise ValueError(f"rate for stream '{sn}' must be > 0")
                for f in range(frames[sn]):
                    push(f / r, "inject", (sn, f))
        else:
            for sn in streams:
                for f in range(min(in_flight, frames[sn])):
                    inject(sn, f, 0.0)

        makespan = 0.0
        while evq:
            t, _, kind, payload = heapq.heappop(evq)
            makespan = max(makespan, t)
            if kind == "inject":
                sn, f = payload
                inject(sn, f, t)
            elif kind == "ready":
                sn, f, nid = payload
                enqueue_ready(sn, f, nid, t)
            elif kind == "arrive":
                sn, f, nid = payload
                missing[(sn, f, nid)] -= 1
                if missing[(sn, f, nid)] == 0:
                    push(t, "ready", (sn, f, nid))
            elif kind == "dispatch":
                (pid,) = payload
                if not pu_idle[pid] or not ready_q[pid]:
                    continue
                _vt, f, _negbl, nid, _tr = heapq.heappop(ready_q[pid])
                sn = view.stream_of[nid]
                dt = exec_time(nid)
                pu_idle[pid] = False
                start = max(t, pu_free_at[pid])
                end = start + dt
                pu_free_at[pid] = end
                if dt > 0:
                    busy_iv[pid].append((start, end))
                    stream_busy[sn][pid] += dt
                push(end, "done", (pid, sn, f, nid))
            elif kind == "done":
                pid, sn, f, nid = payload
                pu_idle[pid] = True
                finish(sn, f, nid, t)
                if ready_q[pid]:
                    push(t, "dispatch", (pid,))
            elif kind == "complete":
                sn, f = payload
                if rates is None and injected[sn] < frames[sn]:
                    inject(sn, injected[sn], t)
        sojourns = {
            sn: [complete_time[(sn, f)] - inject_time[(sn, f)]
                 for f in range(frames[sn]) if (sn, f) in complete_time]
            for sn in streams
        }
        return (makespan, {s: sorted(c) for s, c in completions.items()},
                busy_iv, sojourns, stream_busy)

    @staticmethod
    def _steady_state(completions: List[float]) -> Tuple[float, Tuple[float, float]]:
        """Mean inter-completion gap over the middle half of the run
        (robust to bursty pipelines where per-gap medians mislead)."""
        n = len(completions)
        if n <= 1:
            t = completions[0] if completions else 0.0
            return (t, (0.0, max(t, 1e-18)))
        lo = n // 4
        window = completions[lo:]
        if len(window) < 2 or window[-1] <= window[0]:
            return (completions[-1] / max(n - 1, 1),
                    (completions[0], completions[-1]))
        interval = (window[-1] - window[0]) / (len(window) - 1)
        return interval, (window[0], window[-1])

    @staticmethod
    def _busy_in_window(busy: Dict[int, List[Tuple[float, float]]],
                        w0: float, w1: float) -> Dict[int, float]:
        out = {}
        for pid, ivs in busy.items():
            acc = 0.0
            for a, b in ivs:
                acc += max(0.0, min(b, w1) - max(a, w0))
            out[pid] = acc
        return out


class MultiTenantSimulator(IMCESimulator):
    """Multi-tenant front-end over the shared event loop.

    Every tenant receives its own frame stream.  Two injection regimes:

    * **closed-loop** (``rates=None``): each tenant keeps a bounded number
      of frames in flight and re-injects on completion — the saturated
      operating point; per-tenant rate is the tenant's fair-share
      throughput under contention.
    * **open-loop** (``rates={tenant: frames/s}``): frame ``f`` of a
      tenant is injected at ``f / rate`` regardless of completions, the
      serving-under-traffic operating point; sojourn latency then includes
      queueing behind both the tenant's own backlog and the co-tenants.

    ``run`` returns an aggregate :class:`SimResult` whose ``tenants`` dict
    carries per-tenant rate, steady-state sojourn latency, busy seconds
    and utilization share.
    """

    def __init__(self, graph: MultiTenantGraph,
                 cost_model: Optional[CostModel] = None,
                 max_in_flight: int = 0) -> None:
        if not isinstance(graph, MultiTenantGraph):
            raise TypeError("MultiTenantSimulator needs a MultiTenantGraph")
        super().__init__(graph, cost_model, max_in_flight)

    # -- stream view ----------------------------------------------------------
    def _stream_view(self, a: Assignment) -> _StreamView:
        """One stream per tenant, weighted for start-time fair queueing:
        a tenant's frame f carries virtual time ``f * (its amortized busy
        seconds per frame)``.  Ordering ready work by virtual time
        equalizes *resource* shares instead of completion counts — a light
        tenant streams several frames per heavy-tenant frame rather than
        being locked to the heavy tenant's pace (which would cap aggregate
        rate at n_tenants / heaviest-round)."""
        g: MultiTenantGraph = self.g  # type: ignore[assignment]
        tenants = list(g.tenants)
        tl = a.tenant_load(g, self.cm)
        return _StreamView(
            streams=tenants,
            nodes={t: g.tenant_nodes(t) for t in tenants},
            sources={t: g.tenant_sources(t) for t in tenants},
            sinks={t: g.tenant_sinks(t) for t in tenants},
            stream_of={n: g.tenant_of(n) for n in g.topo_order()},
            weight={t: max(sum(tl.get(t, {0: 0.0}).values()), 1e-18)
                    for t in tenants},
        )

    # -- public API -----------------------------------------------------------
    def run(self, assignment: Assignment, frames: int = 64,
            rates: Optional[Dict[str, float]] = None) -> SimResult:
        g: MultiTenantGraph = self.g  # type: ignore[assignment]
        tenants = list(g.tenants)
        if rates is not None and set(rates) != set(tenants):
            raise ValueError(
                f"rates keys {sorted(rates)} != tenants {sorted(tenants)}")

        # truly isolated per-tenant single-frame makespans: each tenant
        # alone on the fleet, no co-tenant contention (keeps the field's
        # 'isolated' semantics comparable with single-tenant runs; the
        # scalar is the worst tenant).
        iso_by_tenant: Dict[str, float] = {}
        for t in tenants:
            mk, *_ = self._run_streams(
                assignment, {u: (1 if u == t else 0) for u in tenants},
                in_flight=1)
            iso_by_tenant[t] = mk
        isolated = max(iso_by_tenant.values(), default=0.0)

        if rates is None:
            # double-buffered sojourn latency run (paper's latency metric)
            lat_frames = {t: max(frames // 2, 16) for t in tenants}
            _, _, _, lat_sojourns, _ = self._run_streams(
                assignment, lat_frames, in_flight=2)
            in_flight = self.max_in_flight or (len(assignment.pus) + 2)
            makespan, completions, busy_iv, sojourns, tenant_busy = \
                self._run_streams(assignment, {t: frames for t in tenants},
                                  in_flight=in_flight)
        else:
            in_flight = 0  # open loop: injection is time-driven
            makespan, completions, busy_iv, sojourns, tenant_busy = \
                self._run_streams(assignment, {t: frames for t in tenants},
                                  in_flight=0, rates=rates)
            lat_sojourns = sojourns

        def steady_mean(xs: List[float]) -> float:
            if not xs:
                return 0.0
            steady = xs[len(xs) // 4:] or xs
            return sum(steady) / len(steady)

        merged = sorted(t for comps in completions.values() for t in comps)
        interval, util_window = self._steady_state(merged)
        busy_window = self._busy_in_window(busy_iv, *util_window)
        window_span = max(util_window[1] - util_window[0], 1e-18)
        utilization = {p: b / window_span for p, b in busy_window.items()}
        per_frame_busy = self._per_frame_busy(assignment)
        bound = max(per_frame_busy.values()) if per_frame_busy else 0.0

        fleet_busy = sum(sum(d.values()) for d in tenant_busy.values())
        tenant_load = assignment.tenant_load(g, self.cm)
        per_tenant: Dict[str, TenantMetrics] = {}
        for t in tenants:
            t_interval, _ = self._steady_state(completions[t])
            t_busy = tenant_busy.get(t, {})
            per_tenant[t] = TenantMetrics(
                tenant=t,
                frames=len(completions[t]),
                rate=1.0 / t_interval if t_interval > 0 else math.inf,
                interval=t_interval,
                latency=steady_mean(lat_sojourns.get(t, [])),
                bound_interval=max(tenant_load.get(t, {0: 0.0}).values()),
                busy=t_busy,
                utilization_share=(sum(t_busy.values()) / fleet_busy
                                   if fleet_busy > 0 else 0.0),
                injected_rate=None if rates is None else rates[t],
            )

        total_busy = {p: sum(iv[1] - iv[0] for iv in ivs)
                      for p, ivs in busy_iv.items()}
        # aggregate sojourn latency: completion-weighted tenant mean
        agg_latency = (
            sum(m.latency * max(m.frames, 1) for m in per_tenant.values())
            / max(sum(max(m.frames, 1) for m in per_tenant.values()), 1))
        return SimResult(
            latency=agg_latency,
            latency_isolated=isolated,
            interval=interval,
            rate=1.0 / interval if interval > 0 else math.inf,
            makespan=makespan,
            frames=sum(len(c) for c in completions.values()),
            busy=total_busy,
            utilization=utilization,
            mean_utilization=sum(utilization.values()) / max(len(utilization), 1),
            per_frame_busy=per_frame_busy,
            bound_interval=bound,
            meta={"algorithm": assignment.algorithm, "in_flight": in_flight,
                  "tenants": tenants,
                  "latency_isolated_by_tenant": iso_by_tenant,
                  "rates": dict(rates) if rates else None},
            tenants=per_tenant,
        )
