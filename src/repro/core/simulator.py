"""Discrete-event simulator of the IMCE's pipelined compute-and-forward
execution (paper §III/§V).

Model
-----
* Frames (inference requests) stream in; several frames are in flight at
  once, bounded by ``max_in_flight`` (the IMCE PUs "run multiple DNN nodes
  concurrently" with finite DRAM buffering).
* A node instance (frame f, node n) becomes *ready* once every predecessor
  instance has finished AND its output has been forwarded to n's PU
  (transfer over shared DRAM + IPI; zero if producer shares the PU).
* Every PU executes one node at a time (exclusive); among ready instances
  it picks the lowest frame first, then the highest bottom-level (classic
  critical-path list-scheduling tiebreak), then node id.  Transfers are
  DMA — they do not occupy the PU.
* Fused activations cost nothing (inside the PU datapath), matching the
  IMCE.

One event loop, compiled
------------------------
There is exactly one event-loop implementation, ``_run_streams``: it
executes any number of *frame streams* over the graph.  A plain
single-model run is the 1-stream special case (``IMCESimulator``); a
multi-tenant union drives one stream per tenant
(``MultiTenantSimulator``).  The subclasses differ only in the stream
structure/weights they hand the loop and in how ``run`` aggregates the
results.

The loop runs over a precompiled :class:`~repro.core.simcontext.SimContext`:
nodes renumbered to dense ``0..N-1`` indices with flat adjacency,
bottom levels, per-node execution/transfer times and replica phase
tables all hoisted out of the hot path, and per-frame state held in
preallocated slot arrays instead of ``(stream, frame, node)`` dicts.
Contexts are cached on the graph and shared across the three passes of
``run()``, across ``lblp-r`` ``validate_rate`` probes, across
``ElasticSession`` events and across benchmark sweep cells.  The event
sequence is identical to the historical dict-keyed loop (kept in
``core._sim_reference`` as an oracle): in the default ``mode="exact"``
every returned float is bit-identical, pinned by
``tests/test_sim_equivalence.py`` goldens and the property tests in
``tests/test_sim_property.py``.

Periodic steady-state early exit (``mode="periodic"``)
------------------------------------------------------
Deterministic closed-loop runs settle into an exactly periodic regime:
once the complete simulator state (per-PU ready queues, in-flight frame
progress, pending events — all relative to the current time and frame
count) recurs, the future is the past shifted by one period, so the
loop can extrapolate the remaining completions, injections and busy
intervals instead of simulating them.  Exact recurrence almost never
happens in floating point (absolute-time rounding perturbs relative
gaps by ulps), so ``mode="periodic"`` quantizes all execution and
transfer costs onto an integer picosecond grid (exact float arithmetic
below 2**53) where recurrence provably fires, detects it with
exact-match state fingerprints taken at frame completions, and
extrapolates *exactly* on that grid; results are converted back to
seconds on return.  Consequences:

* reported times differ from ``mode="exact"`` only by the ~1e-6
  relative cost quantization plus, on multi-stream runs, the weight
  rationalization described below (well under the model's fidelity);
* the extrapolated tail reports the *infinite-stream periodic regime*
  sampled for ``frames`` completions per stream — the finite-budget
  drain tail (slightly less contention once some stream stops
  injecting) is excluded by design, which is the better steady-state
  estimate;
* open-loop (``rates=``) runs never early-exit; they still benefit from
  the compiled loop and the quantized grid.

Multi-stream steady state (fair-queueing shift invariance)
----------------------------------------------------------
Multi-stream closed-loop runs order ready work by start-time fair
queueing: a frame ``f`` of stream ``s`` carries virtual time
``f * w_s``.  With arbitrary float weights the interleave is
*aperiodic* (the relative order of ``f_s * w_s`` values never repeats —
a Beatty-sequence effect), which is why multi-tenant runs historically
could not early-exit.  In quantized mode the weights themselves are
therefore rationalized (``simcontext.quantize_stream_weights``): each
weight becomes an exact integer whose pairwise ratios are small
rationals, making every virtual-time comparison exact integer
arithmetic.  On such weights the interleave is invariant under shifting
every stream ``s`` by ``dF_s`` frames whenever the *virtual-time
advance* ``dF_s * W_s`` is equal across streams — precisely the
condition a fingerprint match enforces, because the fingerprint records
the quantized virtual-time *gaps* ``injected_s * W_s - injected_0 *
W_0`` between streams alongside the per-slot relative state (stream,
frame offset from that stream's completion count, remaining-sink count,
and an O(1) integer digest of the missing-predecessor vector).
Fingerprints are sampled at stream-0 completions once every stream has
both filled its pipeline and retains injection budget; a match yields
the joint period ``(dF_0..dF_{S-1}, T)`` and all streams' remaining
completions, injections and busy intervals are extrapolated together —
exactly, on the integer grid.  The extrapolation and the tick->seconds
conversion are vectorized with numpy when it is importable (bit-equal
to the scalar fallback: every quantity is an integer-valued float, so
batched arithmetic cannot round differently).

Layer replication (LRMP-style)
------------------------------
Nodes cloned by ``Graph.replicate(node_id, k)`` carry
``replica_index``/``replica_count`` tags; the loop routes frame ``f`` to
replica ``f % k`` (round-robin split) and consumers merge transparently —
an inactive replica simply does not exist for that frame.  The analytic
bound uses the amortized per-frame load (``CostModel.frame_time``).

Measurements
------------
* ``latency``   — the paper's latency metric: mean frame *sojourn* time
  (completion - injection) in double-buffered streaming (``in_flight=2``,
  capture/process overlap, the standard camera-pipeline operating point).
  This reproduces the paper's latency behaviour: it decreases with #PUs
  (queueing shrinks) and converges across algorithms when every node has
  its own PU.  An isolated single-frame makespan is also reported
  (``latency_isolated``); on mostly-sequential CNNs it is
  mapping-invariant up to transfer costs, which is why the streaming
  sojourn must be the figure-of-merit (see EXPERIMENTS.md).
* ``interval``  — steady-state time between consecutive frame completions
  at saturation (deep pipelining); processing rate is ``1/interval``.
* ``utilization`` — per-PU busy fraction over the steady-state window
  (paper Table I).

The analytic pipeline bound ``interval >= max_pu(amortized busy per
frame)`` is asserted (within epsilon) in tests; LBLP's load balancing
minimizes exactly that bound, and LBLP-R lowers it further by
replicating the bottleneck node.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field, replace
from heapq import heappop, heappush
from typing import Dict, List, Optional, Tuple, Union

try:  # vectorized extrapolation/conversion; scalar fallback is bit-equal
    import numpy as _np
except ImportError:  # pragma: no cover - minimal-deps environments
    _np = None

from .cost import CostModel
from .graph import Graph, MultiTenantGraph
from .schedulers.base import Assignment
from .simcontext import (MEMO_CAP, TIME_SCALE, SimContext,
                         quantize_stream_weights)

# event kinds of the compiled loop (ints: never compared by the heap —
# the (time, seq) prefix is already a total order — but cheap to branch on)
_INJECT, _READY, _ARRIVE, _DISPATCH, _DONE, _COMPLETE = range(6)

#: steady-state detection arms only at or beyond this per-stream frame
#: budget (smaller runs have no tail worth extrapolating)
_DETECT_MIN_FRAMES = 24
#: cap on remembered state fingerprints per run (memory guard; a run
#: whose state never recurs within the cap simply completes normally)
_DETECT_MAX_STATES = 512
#: multi-stream detection assumes in-flight frame ids stay within this
#: many frames *behind* the stream's completion count (round-robin
#: replicas complete slightly out of order); a state violating it is
#: simply not sampled, so the bound is safe by construction
_MAX_OOO_FRAMES = 8
#: numpy pays off on the extrapolation/conversion batches only beyond
#: roughly this many items; below it the scalar loops win (identical
#: values either way — the choice is pure speed)
_VECTOR_MIN = 192
#: debug hook: when a list, every detection sample appends (t, rel, key)
_DEBUG_SAMPLES: Optional[list] = None


def slo_headroom(rate: float, latency: float,
                 min_rate: Optional[float] = None,
                 max_latency: Optional[float] = None) -> float:
    """Smallest relative margin of attained figures to a promise:
    positive iff every promised dimension is met (``inf`` when nothing
    is promised).  Rate margin is ``rate/min_rate - 1``; latency margin
    is ``1 - latency/max_latency`` — both are signed fractions of the
    promise, so the min is the binding dimension.  The single source of
    the formula: :meth:`TenantMetrics.slo_headroom` and
    ``serving.SLO.headroom`` both delegate here."""
    h = math.inf
    if min_rate is not None and min_rate > 0:
        h = min(h, rate / min_rate - 1.0)
    if max_latency is not None and max_latency > 0:
        h = min(h, 1.0 - latency / max_latency)
    return h


@dataclass
class TenantMetrics:
    """Steady-state figures of one tenant's frame stream (multi-tenant runs)."""

    tenant: str
    frames: int                         # completed frames
    rate: float                         # tenant frames/s at steady state
    interval: float                     # steady-state per-frame interval [s]
    latency: float                      # mean steady-state sojourn [s]
    bound_interval: float               # tenant's own max per-PU load bound
    busy: Dict[int, float]              # pu_id -> busy seconds for this tenant
    utilization_share: float            # tenant busy / fleet busy (whole run)
    injected_rate: Optional[float] = None  # requested open-loop rate, if any

    # -- SLO evaluation (consumed by repro.core.serving) -------------------
    def slo_headroom(self, min_rate: Optional[float] = None,
                     max_latency: Optional[float] = None) -> float:
        """Smallest relative margin to the promise — see the
        module-level :func:`slo_headroom`."""
        return slo_headroom(self.rate, self.latency, min_rate, max_latency)

    def meets_slo(self, min_rate: Optional[float] = None,
                  max_latency: Optional[float] = None) -> bool:
        return self.slo_headroom(min_rate, max_latency) >= 0.0


@dataclass
class SimResult:
    latency: float                      # streaming sojourn latency [s]
    latency_isolated: float             # single-frame makespan [s]
    interval: float                     # steady-state per-frame interval [s]
    rate: float                         # 1/interval [frames/s]
    makespan: float                     # full streaming-run span [s]
    frames: int
    busy: Dict[int, float]              # pu_id -> busy seconds (whole run)
    utilization: Dict[int, float]       # pu_id -> busy fraction, steady window
    mean_utilization: float
    per_frame_busy: Dict[int, float]    # pu_id -> amortized busy s per frame
    bound_interval: float               # analytic max-load bound
    meta: dict = field(default_factory=dict)
    tenants: Dict[str, TenantMetrics] = field(default_factory=dict)

    # -- SLO evaluation (consumed by repro.core.serving) -------------------
    def slo_headroom(self, slos: Dict[str, Tuple[Optional[float],
                                                 Optional[float]]]
                     ) -> Dict[str, float]:
        """Per-tenant SLO headroom over a ``tenant -> (min_rate,
        max_latency)`` promise map (see
        :meth:`TenantMetrics.slo_headroom`).  Every promised tenant must
        be present in ``self.tenants``."""
        return {t: self.tenants[t].slo_headroom(mr, ml)
                for t, (mr, ml) in slos.items()}

    def meets_slos(self, slos: Dict[str, Tuple[Optional[float],
                                               Optional[float]]]) -> bool:
        return all(h >= 0.0 for h in self.slo_headroom(slos).values())


@dataclass
class _StreamView:
    """How the event loop sees the graph's frame streams.

    ``IMCESimulator`` exposes one stream spanning the whole graph;
    ``MultiTenantSimulator`` exposes one per tenant.  ``weight`` is the
    stream's virtual-time increment per frame (start-time fair queueing);
    for a single stream any positive constant yields the historical
    frame-number ordering.  (The compiled loop consumes the same
    structure via ``SimContext``; this view object remains the interface
    of the reference loop in ``core._sim_reference``.)
    """

    streams: List[str]
    nodes: Dict[str, List[int]]         # stream -> member node ids
    sources: Dict[str, List[int]]       # stream -> source node ids
    sinks: Dict[str, List[int]]         # stream -> sink node ids
    stream_of: Dict[int, str]           # node id -> stream
    weight: Dict[str, float]            # stream -> virtual-time weight


class IMCESimulator:
    """Event-driven executor of an ``Assignment`` over a ``Graph``.

    ``mode="exact"`` (default) reproduces the historical event loop
    bit-for-bit; ``mode="periodic"`` runs on the quantized time grid
    with steady-state early exit (see module docstring).
    """

    _context_kind = "single"

    def __init__(self, graph: Graph, cost_model: Optional[CostModel] = None,
                 max_in_flight: int = 0, mode: str = "exact") -> None:
        self.g = graph
        self.cm = cost_model or CostModel()
        self.max_in_flight = max_in_flight  # 0 -> auto (=|PUs|+2)
        if mode not in ("exact", "periodic"):
            raise ValueError(f"mode must be 'exact' or 'periodic', got {mode!r}")
        self.mode = mode
        # compiled structure, shared via the graph-level cache
        self._ctx = SimContext.for_graph(
            graph, self.cm, self._context_kind, self._stream_structure)
        self._blevel = self._ctx.blevel_by_id
        #: events processed by the most recent ``_run_streams`` call
        self.last_events = 0
        #: ``(frames_per_period, period_seconds)`` when the most recent
        #: run early-exited, else None.  Multi-stream runs report the
        #: per-stream frame shifts as a tuple.
        self.last_early_exit: Optional[Tuple[Union[int, tuple], float]] = None
        # identity-keyed memo of the last assignment's stream weights
        # (``run`` probes the loop several times with one assignment)
        self._wts_cache: Optional[tuple] = None

    # -- public API -----------------------------------------------------------
    def _run_memo_key(self, assignment: Assignment, frames: int,
                      rates: Optional[Dict[str, float]] = None
                      ) -> Optional[tuple]:
        """Content key of a full ``run()`` — the result is a pure
        function of it.  Cached on the shared context so serving the
        same schedule repeatedly (model registries, repeated benchmark
        cells over one graph object) evaluates once."""
        return ("run", type(self).__name__, self.mode, frames,
                self.max_in_flight,
                tuple(sorted(assignment.mapping.items())),
                tuple((p.pu_id, p.pu_type, p.speed, p.weight_capacity)
                      for p in assignment.pus),
                None if rates is None else tuple(sorted(rates.items())))

    @staticmethod
    def _copy_result(res: SimResult) -> SimResult:
        """Copy deep enough that callers mutating a returned result's
        dict fields cannot corrupt the cache entry (dataclasses.replace
        alone would share the nested dicts)."""
        return replace(
            res,
            busy=dict(res.busy),
            utilization=dict(res.utilization),
            per_frame_busy=dict(res.per_frame_busy),
            meta={k: (dict(v) if isinstance(v, dict) else v)
                  for k, v in res.meta.items()},
            tenants={t: replace(m, busy=dict(m.busy))
                     for t, m in res.tenants.items()},
        )

    def _run_memo_get(self, key: tuple) -> Optional[SimResult]:
        hit = self._ctx.memo.get(key)
        if hit is None:
            return None
        res, early_exit, events = hit
        # a hit must leave the diagnostics describing this run, not
        # whatever the simulator did last
        self.last_early_exit = early_exit
        self.last_events = events
        return self._copy_result(res)

    def _run_memo_put(self, key: tuple, res: SimResult) -> None:
        memo = self._ctx.memo
        while len(memo) >= MEMO_CAP:
            memo.pop(next(iter(memo)))
        memo[key] = (self._copy_result(res), self.last_early_exit,
                     self.last_events)

    def run(self, assignment: Assignment, frames: int = 64) -> SimResult:
        """Full evaluation: isolated latency run + double-buffered latency
        run + saturated streaming throughput run."""
        memo_key = self._run_memo_key(assignment, frames)
        hit = self._run_memo_get(memo_key)
        if hit is not None:
            return hit
        isolated, _, _, _ = self._simulate(assignment, frames=1, in_flight=1)
        # double-buffered sojourn latency (the paper's latency metric)
        _, _, _, sojourns = self._simulate(
            assignment, frames=max(frames // 2, 16), in_flight=2
        )
        k = len(sojourns) // 4
        steady = sojourns[k:] or sojourns
        latency = sum(steady) / len(steady)
        in_flight = self.max_in_flight or (len(assignment.pus) + 2)
        makespan, comps_by_stream, busy, _, busy_by_stream = self._run_streams(
            assignment, frames=frames, in_flight=in_flight
        )
        completions = comps_by_stream[next(iter(comps_by_stream))]
        interval, util_window = self._steady_state(completions)
        busy_window = self._busy_in_window(busy, *util_window)
        window_span = max(util_window[1] - util_window[0], 1e-18)
        utilization = {p: b / window_span for p, b in busy_window.items()}
        per_frame_busy = self._per_frame_busy(assignment)
        bound = max(per_frame_busy.values()) if per_frame_busy else 0.0
        if self.mode == "periodic":
            # the loop already accumulated per-stream busy seconds; on
            # the integer grid the sum is exact, no need to re-walk the
            # (possibly extrapolated) interval lists
            total_busy = {p: 0.0 for p in busy}
            for d in busy_by_stream.values():
                for p, v in d.items():
                    total_busy[p] += v
        else:
            total_busy = {p: sum(iv[1] - iv[0] for iv in ivs)
                          for p, ivs in busy.items()}
        res = SimResult(
            latency=latency,
            latency_isolated=isolated,
            interval=interval,
            rate=1.0 / interval if interval > 0 else math.inf,
            makespan=makespan,
            frames=frames,
            busy=total_busy,
            utilization=utilization,
            mean_utilization=sum(utilization.values()) / max(len(utilization), 1),
            per_frame_busy=per_frame_busy,
            bound_interval=bound,
            meta={"algorithm": assignment.algorithm, "in_flight": in_flight},
        )
        self._run_memo_put(memo_key, res)
        return res

    def latency_only(self, assignment: Assignment) -> float:
        """Isolated single-frame makespan."""
        latency, _, _, _ = self._simulate(assignment, frames=1, in_flight=1)
        return latency

    # -- stream structure ------------------------------------------------------
    def _stream_structure(self):
        """One stream spanning the whole graph (single-model serving):
        ``(streams, members, sources, sinks, stream_of)`` with node ids."""
        g = self.g
        key = g.name
        order = g.topo_order()
        return ([key], {key: order}, {key: g.sources()}, {key: g.sinks()},
                {n: key for n in order})

    def _stream_weights(self, a: Assignment) -> Dict[str, float]:
        """Virtual-time weight per stream; any constant for one stream."""
        return {self.g.name: 1.0}

    def _stream_view(self, a: Assignment) -> _StreamView:
        """Legacy view object (consumed by the reference loop)."""
        streams, nodes, sources, sinks, stream_of = self._stream_structure()
        return _StreamView(streams, nodes, sources, sinks, stream_of,
                           self._stream_weights(a))

    # -- internals -----------------------------------------------------------
    def _per_frame_busy(self, a: Assignment) -> Dict[int, float]:
        out = {p.pu_id: 0.0 for p in a.pus}
        for nid, pid in a.mapping.items():
            pu = a.pu_by_id(pid)
            out[pid] += self.cm.frame_time(self.g.nodes[nid], pu.pu_type, pu.speed)
        return out

    def _simulate(self, a: Assignment, frames: int, in_flight: int,
                  ) -> Tuple[float, List[float],
                             Dict[int, List[Tuple[float, float]]], List[float]]:
        """Single-stream adapter over the shared event loop (kept for the
        historical return shape: makespan, completions, busy, sojourns).
        On a multi-stream view every stream gets ``frames`` and the first
        stream's completions/sojourns are reported."""
        makespan, completions, busy_iv, sojourns, _ = self._run_streams(
            a, frames=frames, in_flight=in_flight)
        first = next(iter(completions))
        return makespan, completions[first], busy_iv, sojourns[first]

    def _run_streams(
        self, a: Assignment, frames, in_flight: int,
        rates: Optional[Dict[str, float]] = None,
        light: bool = False,
    ) -> Tuple[float, Dict[str, List[float]],
               Dict[int, List[Tuple[float, float]]],
               Dict[str, List[float]], Dict[str, Dict[int, float]]]:
        """THE event loop: stream-keyed frames over one graph, compiled.

        A frame instance is ``(stream, f)`` and only traverses the
        stream's member nodes; replicated nodes additionally serve only
        the frames of their round-robin slot.  Two injection regimes:
        closed-loop (bounded in-flight, re-inject on completion) and
        open-loop (``rates``: frame f injected at ``f / rate``).

        ``frames`` is a per-stream dict, or an int applied to every
        stream of the view.  Returns ``(makespan, completions-by-stream,
        busy intervals per PU, sojourns-by-stream,
        busy-by-stream-by-PU)``.  ``light`` callers (rate probes) only
        read completions; the busy/sojourn materialization is skipped.
        """
        ctx = self._ctx
        quant = self.mode == "periodic"
        plan = ctx.plan(a, self.cm, quant)
        skeys = ctx.stream_keys
        S = len(skeys)
        if isinstance(frames, int):
            frames = {s: frames for s in skeys}
        fcount = [frames[s] for s in skeys]
        wts = self._cached_weights(a)
        w_arr = [wts[s] for s in skeys]

        n = ctx.n
        node_ids = ctx.ids
        negbl = ctx.negbl
        exec_t = plan.exec_t
        pu_of = plan.pu_of
        npu = len(plan.pu_ids)
        members = ctx.members
        preds = ctx.preds
        succs = ctx.succs
        is_active = ctx.active

        replicated = ctx.replicated
        phased = ctx.phases_compiled
        period = ctx.phase_period
        dyn = replicated and not phased
        arrive_tbl = plan.arrive
        arrive_0 = arrive_tbl[0]
        base_missing = ctx.base_missing
        init_ready = ctx.init_ready
        phase_sinks = ctx.phase_sinks
        base_digest = ctx.base_digest
        dpow = ctx.digest_pow

        detect = (quant and rates is None and not dyn and bool(fcount)
                  and min(fcount) >= _DETECT_MIN_FRAMES)
        if quant and rates is None and S > 1:
            # integer virtual-time weights with small-rational ratios:
            # exact vt arithmetic makes the fair-queueing interleave
            # frame-shift invariant, the precondition for multi-stream
            # steady-state recurrence (see module docstring)
            qw = quantize_stream_weights(w_arr, max(fcount))
            if qw is None:
                detect = False
            else:
                w_arr = qw
        track = detect  # maintain slot digests (fingerprint ingredients)

        # Pairwise virtual-time gaps (multi-stream detection): a
        # cross-stream vt comparison can only depend on the *exact* gap
        # while it sits inside the discrimination band (in-flight frames
        # of both streams could tie); beyond the band the lagging stream
        # has strict priority and only the gap's sign matters — tenants
        # whose steady rates are not in inverse weight ratio drift there
        # and stay (the gap moves monotonically per period).  The
        # fingerprint therefore records the exact gap inside the band
        # and a sign sentinel outside it; sentinel matches are verified
        # against the sampled trail before extrapolating (gap never
        # re-entered the band in the window, and drifts away from it).
        pair_defs = [
            (u, v, (in_flight + _MAX_OOO_FRAMES + 1) * (w_arr[u] + w_arr[v]))
            for u in range(S) for v in range(u + 1, S)
        ] if detect and S > 1 else []

        # events are (time, seq, kind, x, y, z); processing order is the
        # total order by (time, seq), exactly the historical heap order.
        # Two lanes carry them: `evq` (heap) for future events and `dq`
        # (FIFO) for events scheduled at the current instant — same-time
        # events dominate (ready/dispatch/complete, zero-cost transfers)
        # and a deque append/popleft is far cheaper than a heap sift.
        # Routing is a pure optimization: the merge pop below compares
        # (time, seq) across both lanes, so any routing is correct.
        evq: List[Tuple[float, int, int, int, int, int]] = []
        dq: deque = deque()
        now = None  # time of the event being processed
        seq = 0

        # per-frame-slot state (slot = one in-flight frame instance)
        slot_stream: List[int] = []
        slot_frame: List[int] = []
        slot_left: List[int] = []
        slot_missing: List[Optional[List[int]]] = []
        slot_digest: List[int] = []
        free_slots: List[int] = []

        inject_t: List[List[Optional[float]]] = [[None] * fcount[s] for s in range(S)]
        complete_t: List[List[Optional[float]]] = [[None] * fcount[s] for s in range(S)]
        injected = [0] * S
        completions: List[List[float]] = [[] for _ in range(S)]
        ready_q: List[List[tuple]] = [[] for _ in range(npu)]
        pu_free_at = [0.0] * npu
        pu_idle = [True] * npu
        busy_iv: List[List[Tuple[float, float]]] = [[] for _ in range(npu)]
        stream_busy = [[0.0] * npu for _ in range(S)]

        # an exact state match is sound even mid-transient (identical
        # state => identical future), so arm as soon as the pipeline can
        # possibly have filled on every stream
        warmup = max(in_flight, 4)
        armed = False
        fp_map: Dict[tuple, tuple] = {}
        trail: List[Tuple[int, ...]] = []  # per-sample completion vectors
        comp_frames: List[List[int]] = [[] for _ in range(S)]
        busy_frame: List[List[int]] = [[] for _ in range(npu)]
        busy_strm: Optional[List[List[int]]] = \
            [[] for _ in range(npu)] if S > 1 else None
        self.last_early_exit = None

        def push(t: float, kind: int, x: int, y: int, z: int) -> None:
            nonlocal seq
            if t == now:
                dq.append((t, seq, kind, x, y, z))
            else:
                heappush(evq, (t, seq, kind, x, y, z))
            seq += 1

        def inject(s: int, f: int, t: float) -> None:
            inject_t[s][f] = t
            if free_slots:
                slot = free_slots.pop()
            else:
                slot = len(slot_frame)
                slot_stream.append(0)
                slot_frame.append(0)
                slot_left.append(0)
                slot_missing.append(None)
                slot_digest.append(0)
            slot_stream[slot] = s
            slot_frame[slot] = f
            if not dyn:
                ph = f % period
                slot_missing[slot] = base_missing[s][ph][:]
                slot_left[slot] = phase_sinks[s][ph]
                if track:
                    slot_digest[slot] = base_digest[s][ph]
                for j in init_ready[s][ph]:
                    push(t, _READY, slot, j, 0)
            else:
                # per-frame view: inactive replicas do not exist for f
                # (lcm of replica counts too large to precompile phases)
                miss = [0] * n
                sinks = 0
                for j in members[s]:
                    if not is_active(j, f):
                        continue
                    miss[j] = sum(1 for p in preds[j] if is_active(p, f))
                    if not any(is_active(k, f) for k in succs[j]):
                        sinks += 1
                    if miss[j] == 0:
                        push(t, _READY, slot, j, 0)
                slot_missing[slot] = miss
                slot_left[slot] = sinks
            injected[s] += 1

        def fingerprint(t: float, rel: List[int]) -> Optional[tuple]:
            """Canonical relative state at a stream-0 frame completion:
            identical fingerprints => identical future evolution shifted
            in time and per-stream frame numbers (exact on the quantized
            grid with integer virtual-time weights).  ``rel`` is the
            per-stream completion count, the frame-number reference.
            Returns None when the state violates the bounded
            out-of-order assumption the gap band relies on."""
            ev = []
            for (te, _sq, k, x, y, z) in sorted(list(evq) + list(dq)):
                if k == _READY or k == _ARRIVE:
                    sx = slot_stream[x]
                    ev.append((te - t, k, sx, slot_frame[x] - rel[sx], y))
                elif k == _DISPATCH:
                    ev.append((te - t, k, x, 0))
                elif k == _DONE:
                    sy = slot_stream[y]
                    ev.append((te - t, k, sy, slot_frame[y] - rel[sy], z, x))
                else:  # _COMPLETE
                    sx = slot_stream[x]
                    ev.append((te - t, k, sx, slot_frame[x] - rel[sx]))
            rq = tuple(
                tuple(sorted(
                    (slot_stream[e[5]], e[1] - rel[slot_stream[e[5]]], e[3])
                    for e in ready_q[p]))
                for p in range(npu)
            )
            frees = set(free_slots)
            slots = []
            for i in range(len(slot_frame)):
                if i in frees:
                    continue
                off = slot_frame[i] - rel[slot_stream[i]]
                if off < -_MAX_OOO_FRAMES and pair_defs:
                    return None
                slots.append((slot_stream[i], off, slot_left[i],
                              slot_digest[i]))
            slots.sort()
            # quantized virtual-time gaps per stream pair, clamped at
            # the discrimination band: inside it equality forces the
            # pair's dF_s * W_s to one constant (the shift-invariance
            # condition); outside it only the saturated sign is state
            gaps = []
            for (u, v, band) in pair_defs:
                gp = rel[u] * w_arr[u] - rel[v] * w_arr[v]
                if gp > band:
                    gp = math.inf
                elif gp < -band:
                    gp = -math.inf
                gaps.append(gp)
            phases = (tuple(r % period for r in rel) if replicated else None)
            return (tuple(injected[x] - rel[x] for x in range(S)),
                    phases, tuple(gaps), tuple(ev), rq, tuple(pu_idle),
                    tuple(slots))

        def clamped_gaps_ok(i1: int, i2: int, rel: List[int]) -> bool:
            """A sentinel (clamped) gap match is sound iff over the whole
            sampled window the pair's gap kept its sign, stayed clear of
            the discrimination band even between samples (adverse
            per-interval movement subtracted), and the per-period drift
            points away from the band — then every future comparison
            resolves exactly as in the window."""
            for (u, v, band) in pair_defs:
                g2 = rel[u] * w_arr[u] - rel[v] * w_arr[v]
                if -band <= g2 <= band:
                    continue  # exact pair: equality enforced by the key
                sgn = 1.0 if g2 > 0 else -1.0
                g1 = None
                m = abs(g2)
                slack = 0.0
                prev = None
                for i in range(i1, i2 + 1):
                    r = trail[i]
                    gi = r[u] * w_arr[u] - r[v] * w_arr[v]
                    if gi * sgn <= 0:
                        return False
                    if g1 is None:
                        g1 = gi
                    if abs(gi) < m:
                        m = abs(gi)
                    if prev is not None:
                        adverse = ((r[v] - prev[v]) * w_arr[v] if sgn > 0
                                   else (r[u] - prev[u]) * w_arr[u])
                        if adverse > slack:
                            slack = adverse
                    prev = r
                drift = g2 - g1
                if drift != 0 and (drift > 0) != (sgn > 0):
                    return False
                if not m - slack > band:
                    return False
            return True

        # prime / schedule injections
        if rates is not None:
            for s in range(S):
                r = rates[skeys[s]]
                if r <= 0:
                    raise ValueError(f"rate for stream '{skeys[s]}' must be > 0")
                for f in range(fcount[s]):
                    ti = f / r
                    if quant:  # injection times live on the tick grid too
                        ti = float(round(ti * TIME_SCALE))
                    push(ti, _INJECT, s, f, 0)
        else:
            for s in range(S):
                for f in range(min(in_flight, fcount[s])):
                    inject(s, f, 0.0)

        # local bindings: every name below is hit hundreds of thousands
        # of times per run, and LOAD_FAST beats LOAD_GLOBAL/method lookup
        hpush, hpop = heappush, heappop
        dq_append, dq_popleft = dq.append, dq.popleft
        # quant mode processes a ready PU's dispatch inline instead of
        # routing it through the queue (the event round-trip is ~25% of
        # all traffic).  Same-tick races resolve slightly differently
        # than the historical order, which is within the quantized
        # mode's fidelity contract; exact mode keeps the queued path
        # bit-for-bit.
        fuse = quant

        makespan = 0.0
        while True:
            # merge pop: smallest (time, seq) across the two lanes
            if dq:
                if evq:
                    eh = evq[0]
                    dh = dq[0]
                    if eh[0] < dh[0] or (eh[0] == dh[0] and eh[1] < dh[1]):
                        ev = hpop(evq)
                    else:
                        ev = dq_popleft()
                else:
                    ev = dq_popleft()
            elif evq:
                ev = hpop(evq)
            else:
                break
            t, _, kind, x, y, z = ev
            now = t
            makespan = t  # event times are nondecreasing
            if kind == _DISPATCH:
                p = x
                rq = ready_q[p]
                if not pu_idle[p] or not rq:
                    continue
                _vt, f, _nb, _nid, j, slot = hpop(rq)
                dt = exec_t[j]
                pu_idle[p] = False
                free_at = pu_free_at[p]
                start = t if t > free_at else free_at
                end = start + dt
                pu_free_at[p] = end
                if dt > 0:
                    busy_iv[p].append((start, end))
                    s = slot_stream[slot]
                    stream_busy[s][p] += dt
                    if detect:
                        busy_frame[p].append(f)
                        if busy_strm is not None:
                            busy_strm[p].append(s)
                    hpush(evq, (end, seq, _DONE, p, slot, j))
                elif end == t:
                    dq_append((end, seq, _DONE, p, slot, j))
                else:
                    hpush(evq, (end, seq, _DONE, p, slot, j))
                seq += 1
            elif kind == _DONE:
                p, slot, j = x, y, z
                pu_idle[p] = True
                s = slot_stream[slot]
                f = slot_frame[slot]
                if dyn:
                    outs = [pr for pr in arrive_0[j] if is_active(pr[0], f)]
                elif replicated:
                    outs = arrive_tbl[f % period][j]
                else:
                    outs = arrive_0[j]
                if not outs:
                    slot_left[slot] -= 1
                    if slot_left[slot] == 0:
                        completions[s].append(t)
                        complete_t[s][f] = t
                        if detect:
                            comp_frames[s].append(f)
                        dq_append((t, seq, _COMPLETE, slot, 0, 0))
                        seq += 1
                else:
                    for k, xf in outs:
                        if xf:
                            hpush(evq, (t + xf, seq, _ARRIVE, slot, k, 0))
                        else:
                            dq_append((t, seq, _ARRIVE, slot, k, 0))
                        seq += 1
                if ready_q[p]:
                    if fuse:
                        # fused dispatch (quant): run the queued-dispatch
                        # body immediately — the PU is idle and has ready
                        # work, so the event round-trip is pure overhead.
                        # Same-tick races resolve slightly differently
                        # than the historical queued order, within the
                        # quantized mode's fidelity contract; exact mode
                        # always takes the queued path, bit-for-bit.
                        # NOTE: this body is intentionally inlined (a
                        # closure call costs as much as it saves) and
                        # must stay textually identical to the _DISPATCH
                        # handler body and the _READY fused copy below.
                        _vt, f, _nb, _nid, j, slot = hpop(ready_q[p])
                        dt = exec_t[j]
                        pu_idle[p] = False
                        free_at = pu_free_at[p]
                        start = t if t > free_at else free_at
                        end = start + dt
                        pu_free_at[p] = end
                        if dt > 0:
                            busy_iv[p].append((start, end))
                            s = slot_stream[slot]
                            stream_busy[s][p] += dt
                            if detect:
                                busy_frame[p].append(f)
                                if busy_strm is not None:
                                    busy_strm[p].append(s)
                            hpush(evq, (end, seq, _DONE, p, slot, j))
                        elif end == t:
                            dq_append((end, seq, _DONE, p, slot, j))
                        else:
                            hpush(evq, (end, seq, _DONE, p, slot, j))
                        seq += 1
                    else:
                        dq_append((t, seq, _DISPATCH, p, 0, 0))
                        seq += 1
            elif kind == _ARRIVE:
                slot, j = x, y
                m = slot_missing[slot]
                m[j] -= 1
                if track:
                    slot_digest[slot] -= dpow[j]
                if m[j] == 0:
                    dq_append((t, seq, _READY, slot, j, 0))
                    seq += 1
            elif kind == _READY:
                slot, j = x, y
                s = slot_stream[slot]
                f = slot_frame[slot]
                p = pu_of[j]
                hpush(ready_q[p],
                      (f * w_arr[s], f, negbl[j], node_ids[j], j, slot))
                if pu_idle[p]:
                    free_at = pu_free_at[p]
                    te = t if t > free_at else free_at
                    if te == t:
                        if fuse:
                            # fused dispatch — keep identical to the
                            # _DONE fused copy (te == t implies
                            # free_at <= t, so the clamp is a no-op)
                            _vt, f, _nb, _nid, j, slot = hpop(ready_q[p])
                            dt = exec_t[j]
                            pu_idle[p] = False
                            free_at = pu_free_at[p]
                            start = t if t > free_at else free_at
                            end = start + dt
                            pu_free_at[p] = end
                            if dt > 0:
                                busy_iv[p].append((start, end))
                                s = slot_stream[slot]
                                stream_busy[s][p] += dt
                                if detect:
                                    busy_frame[p].append(f)
                                    if busy_strm is not None:
                                        busy_strm[p].append(s)
                                hpush(evq, (end, seq, _DONE, p, slot, j))
                            elif end == t:
                                dq_append((end, seq, _DONE, p, slot, j))
                            else:
                                hpush(evq, (end, seq, _DONE, p, slot, j))
                            seq += 1
                        else:
                            dq_append((te, seq, _DISPATCH, p, 0, 0))
                            seq += 1
                    else:
                        hpush(evq, (te, seq, _DISPATCH, p, 0, 0))
                        seq += 1
            elif kind == _COMPLETE:
                slot = x
                s = slot_stream[slot]
                free_slots.append(slot)
                if rates is None and injected[s] < fcount[s]:
                    inject(s, injected[s], t)
                if detect and s == 0:
                    if any(injected[x] >= fcount[x] for x in range(S)):
                        # some stream started draining: the closed-loop
                        # regime the fingerprints describe has ended
                        detect = False
                        continue
                    if not armed:
                        armed = all(len(completions[x]) >= warmup
                                    for x in range(S))
                    if armed:
                        rel = [len(completions[x]) for x in range(S)]
                        key = fingerprint(t, rel)
                        if key is None:
                            continue
                        if _DEBUG_SAMPLES is not None:
                            _DEBUG_SAMPLES.append((t, tuple(rel), key))
                        trail.append(tuple(rel))
                        entry = (t, tuple(rel),
                                 tuple(len(busy_iv[p]) for p in range(npu)),
                                 len(trail) - 1)
                        prev = fp_map.get(key)
                        if prev is None:
                            if len(fp_map) < _DETECT_MAX_STATES:
                                fp_map[key] = entry
                            else:
                                # state space too large to recur within the
                                # cap: stop paying for fingerprints and run
                                # the rest of the simulation plainly
                                detect = False
                        else:
                            t0, rel0, blens, i1 = prev
                            T = t - t0
                            dF = [rel[x] - rel0[x] for x in range(S)]
                            if not (T > 0 and all(dF)) or (
                                    pair_defs and not clamped_gaps_ok(
                                        i1, len(trail) - 1, rel)):
                                # not (yet) a provably recurring state:
                                # keep the fresher sample — its clamped
                                # gaps have drifted further, so a later
                                # match verifies more easily
                                fp_map[key] = entry
                                continue
                            self._extrapolate(
                                fcount, dF, T, rel0, rel,
                                completions, comp_frames, complete_t,
                                inject_t, injected, busy_iv,
                                busy_frame, busy_strm, blens, stream_busy,
                                light)
                            self.last_early_exit = (
                                dF[0] if S == 1 else tuple(dF),
                                T / TIME_SCALE if quant else T)
                            makespan = max(max(completions[x])
                                           for x in range(S))
                            break
            else:  # _INJECT (open loop)
                inject(x, y, t)

        self.last_events = seq
        if not quant:
            sojourns_g = {
                skeys[s]: [complete_t[s][f] - inject_t[s][f]
                           for f in range(fcount[s])
                           if complete_t[s][f] is not None]
                for s in range(S)
            }
            return (makespan,
                    {skeys[s]: sorted(completions[s]) for s in range(S)},
                    {plan.pu_ids[p]: busy_iv[p] for p in range(npu)},
                    sojourns_g,
                    {skeys[s]: {plan.pu_ids[p]: stream_busy[s][p]
                                for p in range(npu)} for s in range(S)})
        return self._to_seconds(plan, skeys, fcount, makespan, completions,
                                complete_t, inject_t, busy_iv, stream_busy,
                                light)

    @staticmethod
    def _to_seconds(plan, skeys, fcount, makespan, completions, complete_t,
                    inject_t, busy_iv, stream_busy, light=False):
        """Quantized tick grid -> seconds, vectorized when numpy is
        importable (identical values: each element is divided by the
        scale exactly as the scalar path would).  ``light`` callers get
        empty busy/sojourn structures (they only read completions)."""
        S = len(skeys)
        npu = len(plan.pu_ids)
        sc = TIME_SCALE
        if light:
            sojourns_g = {skeys[s]: [] for s in range(S)}
        else:
            sojourns_g = {
                skeys[s]: [(complete_t[s][f] - inject_t[s][f]) / sc
                           for f in range(fcount[s])
                           if complete_t[s][f] is not None]
                for s in range(S)
            }
        comps = {}
        for s in range(S):
            cs = completions[s]
            if _np is not None and len(cs) >= _VECTOR_MIN:
                arr = _np.asarray(cs) / sc
                arr.sort()
                comps[skeys[s]] = arr.tolist()
            else:
                comps[skeys[s]] = sorted(c / sc for c in cs)
        busy = {}
        for p in range(npu):
            # always scalar: numpy round-trips through tuple lists cost
            # more than the comprehension at every size
            ivs = () if light else busy_iv[p]
            busy[plan.pu_ids[p]] = [(b / sc, e / sc) for (b, e) in ivs]
        return (
            makespan / sc,
            comps,
            busy,
            sojourns_g,
            {skeys[s]: {plan.pu_ids[p]: stream_busy[s][p] / sc
                        for p in range(npu)} for s in range(S)},
        )

    def _weights_sig(self) -> Optional[tuple]:
        """Content signature of the serving-weight knobs the stream
        weights depend on (None when there are none)."""
        return None

    def _cached_weights(self, a: Assignment) -> Dict[str, float]:
        sig = self._weights_sig()
        hit = self._wts_cache
        if hit is not None and hit[0] is a and hit[2] == sig:
            return hit[1]
        wts = self._stream_weights(a)
        self._wts_cache = (a, wts, sig)
        return wts

    @staticmethod
    def _extrapolate(fcount: List[int], dF: List[int], T: float,
                     rel0: Tuple[int, ...], rel1: List[int],
                     completions: List[List[float]],
                     comp_frames: List[List[int]],
                     complete_t: List[List[Optional[float]]],
                     inject_t: List[List[Optional[float]]],
                     injected: List[int],
                     busy_iv: List[List[Tuple[float, float]]],
                     busy_frame: List[List[int]],
                     busy_strm: Optional[List[List[int]]],
                     blens: Tuple[int, ...],
                     stream_busy: List[List[float]],
                     light: bool = False) -> None:
        """Exact periodic extrapolation, all streams jointly: the window
        between the two matched states (``dF[s]`` frames of stream ``s``
        over ``T`` ticks) repeats verbatim, shifted by multiples of
        ``(dF, T)``, until every stream's frame budget is met.  All
        arithmetic stays on the integer grid, so the result equals a
        full simulation of the never-draining periodic regime — whether
        it runs through numpy (batched) or the scalar fallback."""
        S = len(fcount)
        # completions (and per-frame completion times for sojourns)
        for s in range(S):
            F, d = fcount[s], dF[s]
            ct_list = complete_t[s]
            frames_w = comp_frames[s][rel0[s]:rel1[s]]
            times_w = completions[s][rel0[s]:rel1[s]]
            if (_np is not None and frames_w
                    and (F - rel1[s]) >= _VECTOR_MIN):
                fw = _np.asarray(frames_w, dtype=_np.int64)
                tw = _np.asarray(times_w)
                k = _np.maximum((F - 1 - fw) // d, 0)
                tot = int(k.sum())
                if tot:
                    idx = _np.repeat(_np.arange(len(fw)), k)
                    csum = _np.concatenate(([0], _np.cumsum(k)[:-1]))
                    step = _np.arange(1, tot + 1) - _np.repeat(csum, k)
                    newt = (tw[idx] + step * T).tolist()
                    completions[s].extend(newt)
                    for f, ct in zip((fw[idx] + step * d).tolist(), newt):
                        ct_list[f] = ct
            else:
                for r in range(len(frames_w)):
                    f = frames_w[r] + d
                    ct = times_w[r] + T
                    while f < F:
                        ct_list[f] = ct
                        completions[s].append(ct)
                        f += d
                        ct += T
            # injections are frame-contiguous in the closed loop
            start = injected[s]
            if _np is not None and start < F and (F - start) >= _VECTOR_MIN:
                fs = _np.arange(start, F, dtype=_np.int64)
                ks = (fs - start) // d + 1
                base = (fs - ks * d).tolist()
                inj = inject_t[s]
                inject_t[s][start:F] = [inj[b] + kk * T
                                        for b, kk in zip(base, ks.tolist())]
            else:
                inj = inject_t[s]
                for f in range(start, F):
                    inj[f] = inj[f - d] + T
        # busy intervals, tagged by (stream, frame) so every stream's
        # budget cut stays exact; rate probes (light) never read them
        for p, ivs in enumerate(() if light else busy_iv):
            if blens[p] >= len(ivs):
                continue
            lo = blens[p]
            frames_p = busy_frame[p]
            if _np is not None and (len(ivs) - lo) >= _VECTOR_MIN // 4:
                fa = _np.asarray(frames_p[lo:], dtype=_np.int64)
                if busy_strm is not None:
                    sa = _np.asarray(busy_strm[p][lo:], dtype=_np.int64)
                    darr = _np.asarray(dF, dtype=_np.int64)[sa]
                    Farr = _np.asarray(fcount, dtype=_np.int64)[sa]
                else:
                    sa = None
                    darr = dF[0]
                    Farr = fcount[0]
                k = _np.maximum((Farr - 1 - fa) // darr, 0)
                tot = int(k.sum())
                if not tot:
                    continue
                be = _np.asarray(ivs[lo:])
                idx = _np.repeat(_np.arange(len(fa)), k)
                csum = _np.concatenate(([0], _np.cumsum(k)[:-1]))
                step = _np.arange(1, tot + 1) - _np.repeat(csum, k)
                shift = step * T
                nb = be[idx, 0] + shift
                ne = be[idx, 1] + shift
                dur = be[idx, 1] - be[idx, 0]
                ivs.extend(zip(nb.tolist(), ne.tolist()))
                if sa is None:
                    stream_busy[0][p] += float(dur.sum())
                else:
                    add = _np.bincount(sa[idx], weights=dur, minlength=S)
                    for s in range(S):
                        stream_busy[s][p] += float(add[s])
            else:
                strm_p = busy_strm[p] if busy_strm is not None else None
                adds = [0.0] * S
                for r in range(lo, len(ivs)):
                    b, e = ivs[r]
                    s = strm_p[r] if strm_p is not None else 0
                    F, d = fcount[s], dF[s]
                    f = frames_p[r] + d
                    dur = e - b
                    bb = b + T
                    while f < F:
                        ivs.append((bb, bb + dur))
                        adds[s] += dur
                        f += d
                        bb += T
                for s in range(S):
                    stream_busy[s][p] += adds[s]
        for s in range(S):
            if (any(c is None for c in complete_t[s])
                    or len(completions[s]) != fcount[s]):
                raise RuntimeError(
                    "periodic extrapolation lost frames — this is a bug; "
                    "re-run with mode='exact'")

    @staticmethod
    def _steady_state(completions: List[float]) -> Tuple[float, Tuple[float, float]]:
        """Mean inter-completion gap over the middle half of the run
        (robust to bursty pipelines where per-gap medians mislead)."""
        n = len(completions)
        if n <= 1:
            t = completions[0] if completions else 0.0
            return (t, (0.0, max(t, 1e-18)))
        lo = n // 4
        window = completions[lo:]
        if len(window) < 2 or window[-1] <= window[0]:
            return (completions[-1] / max(n - 1, 1),
                    (completions[0], completions[-1]))
        interval = (window[-1] - window[0]) / (len(window) - 1)
        return interval, (window[0], window[-1])

    @staticmethod
    def _busy_in_window(busy: Dict[int, List[Tuple[float, float]]],
                        w0: float, w1: float) -> Dict[int, float]:
        out = {}
        for pid, ivs in busy.items():
            acc = 0.0
            for a, b in ivs:
                if b <= w0 or a >= w1:
                    continue
                lo = a if a > w0 else w0
                hi = b if b < w1 else w1
                acc += hi - lo
            out[pid] = acc
        return out


class MultiTenantSimulator(IMCESimulator):
    """Multi-tenant front-end over the shared event loop.

    Every tenant receives its own frame stream.  Two injection regimes:

    * **closed-loop** (``rates=None``): each tenant keeps a bounded number
      of frames in flight and re-injects on completion — the saturated
      operating point; per-tenant rate is the tenant's fair-share
      throughput under contention.
    * **open-loop** (``rates={tenant: frames/s}``): frame ``f`` of a
      tenant is injected at ``f / rate`` regardless of completions, the
      serving-under-traffic operating point; sojourn latency then includes
      queueing behind both the tenant's own backlog and the co-tenants.

    ``run`` returns an aggregate :class:`SimResult` whose ``tenants`` dict
    carries per-tenant rate, steady-state sojourn latency, busy seconds
    and utilization share.
    """

    _context_kind = "mt"

    def __init__(self, graph: MultiTenantGraph,
                 cost_model: Optional[CostModel] = None,
                 max_in_flight: int = 0, mode: str = "exact") -> None:
        if not isinstance(graph, MultiTenantGraph):
            raise TypeError("MultiTenantSimulator needs a MultiTenantGraph")
        super().__init__(graph, cost_model, max_in_flight, mode)

    # -- stream structure ------------------------------------------------------
    def _stream_structure(self):
        """One stream per tenant."""
        g: MultiTenantGraph = self.g  # type: ignore[assignment]
        tenants = list(g.tenants)
        return (tenants,
                {t: g.tenant_nodes(t) for t in tenants},
                {t: g.tenant_sources(t) for t in tenants},
                {t: g.tenant_sinks(t) for t in tenants},
                {n: g.tenant_of(n) for n in g.topo_order()})

    def _stream_weights(self, a: Assignment) -> Dict[str, float]:
        """Start-time fair queueing weights: a tenant's frame f carries
        virtual time ``f * (its amortized busy seconds per frame)``.
        Ordering ready work by virtual time equalizes *resource* shares
        instead of completion counts — a light tenant streams several
        frames per heavy-tenant frame rather than being locked to the
        heavy tenant's pace (which would cap aggregate rate at
        n_tenants / heaviest-round).

        Per-tenant serving weights (``MultiTenantGraph.tenant_weight``)
        scale the entitlement: dividing the virtual-time increment by
        the weight gives a weight-w tenant w times the fleet share of a
        weight-1 tenant (classic weighted fair queueing).  The default
        weight of 1.0 reproduces the historical equal-share ordering
        bit-for-bit."""
        g: MultiTenantGraph = self.g  # type: ignore[assignment]
        tl = self._cached_tenant_load(a)
        return {t: (max(sum(tl.get(t, {0: 0.0}).values()), 1e-18)
                    / g.tenant_weight(t))
                for t in g.tenants}

    def _cached_tenant_load(self, a: Assignment):
        hit = getattr(self, "_tl_cache", None)
        if hit is not None and hit[0] is a:
            return hit[1]
        tl = a.tenant_load(self.g, self.cm)
        self._tl_cache = (a, tl)
        return tl

    def _run_memo_key(self, assignment: Assignment, frames: int,
                      rates: Optional[Dict[str, float]] = None
                      ) -> Optional[tuple]:
        # tenant serving weights change the fair-queueing interleave
        # without any structural mutation, so the content key must carry
        # them (the serving tier re-weights tenants on one union object)
        g: MultiTenantGraph = self.g  # type: ignore[assignment]
        base = super()._run_memo_key(assignment, frames, rates)
        return base + (tuple(g.tenant_weight(t) for t in g.tenants),)

    def _weights_sig(self) -> Optional[tuple]:
        g: MultiTenantGraph = self.g  # type: ignore[assignment]
        return tuple(g.tenant_weight(t) for t in g.tenants)

    # -- public API -----------------------------------------------------------
    def run(self, assignment: Assignment, frames: int = 64,
            rates: Optional[Dict[str, float]] = None) -> SimResult:
        g: MultiTenantGraph = self.g  # type: ignore[assignment]
        tenants = list(g.tenants)
        if rates is not None and set(rates) != set(tenants):
            raise ValueError(
                f"rates keys {sorted(rates)} != tenants {sorted(tenants)}")
        memo_key = self._run_memo_key(assignment, frames, rates)
        hit = self._run_memo_get(memo_key)
        if hit is not None:
            return hit

        # truly isolated per-tenant single-frame makespans: each tenant
        # alone on the fleet, no co-tenant contention (keeps the field's
        # 'isolated' semantics comparable with single-tenant runs; the
        # scalar is the worst tenant).
        iso_by_tenant: Dict[str, float] = {}
        for t in tenants:
            mk, *_ = self._run_streams(
                assignment, {u: (1 if u == t else 0) for u in tenants},
                in_flight=1)
            iso_by_tenant[t] = mk
        isolated = max(iso_by_tenant.values(), default=0.0)

        if rates is None:
            # double-buffered sojourn latency run (paper's latency metric)
            lat_frames = {t: max(frames // 2, 16) for t in tenants}
            _, _, _, lat_sojourns, _ = self._run_streams(
                assignment, lat_frames, in_flight=2)
            in_flight = self.max_in_flight or (len(assignment.pus) + 2)
            makespan, completions, busy_iv, sojourns, tenant_busy = \
                self._run_streams(assignment, {t: frames for t in tenants},
                                  in_flight=in_flight)
        else:
            in_flight = 0  # open loop: injection is time-driven
            makespan, completions, busy_iv, sojourns, tenant_busy = \
                self._run_streams(assignment, {t: frames for t in tenants},
                                  in_flight=0, rates=rates)
            lat_sojourns = sojourns

        def steady_mean(xs: List[float]) -> float:
            if not xs:
                return 0.0
            steady = xs[len(xs) // 4:] or xs
            return sum(steady) / len(steady)

        merged = sorted(t for comps in completions.values() for t in comps)
        interval, util_window = self._steady_state(merged)
        busy_window = self._busy_in_window(busy_iv, *util_window)
        window_span = max(util_window[1] - util_window[0], 1e-18)
        utilization = {p: b / window_span for p, b in busy_window.items()}
        per_frame_busy = self._per_frame_busy(assignment)
        bound = max(per_frame_busy.values()) if per_frame_busy else 0.0

        fleet_busy = sum(sum(d.values()) for d in tenant_busy.values())
        tenant_load = self._cached_tenant_load(assignment)
        per_tenant: Dict[str, TenantMetrics] = {}
        for t in tenants:
            t_interval, _ = self._steady_state(completions[t])
            t_busy = tenant_busy.get(t, {})
            per_tenant[t] = TenantMetrics(
                tenant=t,
                frames=len(completions[t]),
                rate=1.0 / t_interval if t_interval > 0 else math.inf,
                interval=t_interval,
                latency=steady_mean(lat_sojourns.get(t, [])),
                bound_interval=max(tenant_load.get(t, {0: 0.0}).values()),
                busy=t_busy,
                utilization_share=(sum(t_busy.values()) / fleet_busy
                                   if fleet_busy > 0 else 0.0),
                injected_rate=None if rates is None else rates[t],
            )

        if self.mode == "periodic":
            total_busy = {p: 0.0 for p in busy_iv}
            for d in tenant_busy.values():
                for p, v in d.items():
                    total_busy[p] += v
        else:
            total_busy = {p: sum(iv[1] - iv[0] for iv in ivs)
                          for p, ivs in busy_iv.items()}
        # aggregate sojourn latency: completion-weighted tenant mean
        agg_latency = (
            sum(m.latency * max(m.frames, 1) for m in per_tenant.values())
            / max(sum(max(m.frames, 1) for m in per_tenant.values()), 1))
        res = SimResult(
            latency=agg_latency,
            latency_isolated=isolated,
            interval=interval,
            rate=1.0 / interval if interval > 0 else math.inf,
            makespan=makespan,
            frames=sum(len(c) for c in completions.values()),
            busy=total_busy,
            utilization=utilization,
            mean_utilization=sum(utilization.values()) / max(len(utilization), 1),
            per_frame_busy=per_frame_busy,
            bound_interval=bound,
            meta={"algorithm": assignment.algorithm, "in_flight": in_flight,
                  "tenants": tenants,
                  "latency_isolated_by_tenant": iso_by_tenant,
                  "rates": dict(rates) if rates else None},
            tenants=per_tenant,
        )
        self._run_memo_put(memo_key, res)
        return res
