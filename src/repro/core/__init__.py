"""repro.core — the paper's contribution: node-to-PU scheduling for
hybrid in-memory-computing inference engines, plus the IMCE simulator.
"""

from .cost import (
    CostModel,
    HardwareProfile,
    IMCE_DEFAULT,
    IMCE_FAST_LINK,
    PUSpec,
    make_pus,
)
from .graph import Graph, GraphError, MultiTenantGraph, Node, OpKind, PUType
from .metrics import NormalizedPoint, normalize, utilization_table
from .schedulers import (
    Assignment,
    ScheduleError,
    Scheduler,
    available,
    get_scheduler,
    schedule_replicated,
)
from .simcontext import TIME_SCALE, SimContext
from .simulator import (
    IMCESimulator,
    MultiTenantSimulator,
    SimResult,
    TenantMetrics,
)


def make_simulator(graph, cost_model=None, engine: str = "exact",
                   max_in_flight: int = 0):
    """Simulator factory over the three engines.

    ``engine`` is ``"exact"`` (compiled loop, bit-identical to the
    historical simulator), ``"periodic"`` (quantized time grid +
    steady-state early exit; the benchmark default) or ``"reference"``
    (the frozen pre-compilation loop kept for equivalence testing and
    honest speedup measurement).  Returns the multi-tenant front-end
    automatically for :class:`MultiTenantGraph` inputs.
    """
    multi = isinstance(graph, MultiTenantGraph)
    if engine == "reference":
        from ._sim_reference import (ReferenceMultiTenantSimulator,
                                     ReferenceSimulator)
        cls = ReferenceMultiTenantSimulator if multi else ReferenceSimulator
        return cls(graph, cost_model, max_in_flight)
    cls = MultiTenantSimulator if multi else IMCESimulator
    return cls(graph, cost_model, max_in_flight, mode=engine)


# imported after make_simulator exists: serving builds on the factory
from .serving import (  # noqa: E402  (deliberate late import)
    SLO,
    Decision,
    ServingControlPlane,
    SLOReport,
    TraceEvent,
    aggregate_goodput,
    dump_trace,
    load_trace,
)

__all__ = [
    "CostModel",
    "HardwareProfile",
    "IMCE_DEFAULT",
    "IMCE_FAST_LINK",
    "PUSpec",
    "make_pus",
    "Graph",
    "GraphError",
    "MultiTenantGraph",
    "Node",
    "OpKind",
    "PUType",
    "NormalizedPoint",
    "normalize",
    "utilization_table",
    "Assignment",
    "ScheduleError",
    "Scheduler",
    "available",
    "get_scheduler",
    "schedule_replicated",
    "IMCESimulator",
    "MultiTenantSimulator",
    "SimResult",
    "TenantMetrics",
    "SimContext",
    "TIME_SCALE",
    "make_simulator",
    "SLO",
    "Decision",
    "ServingControlPlane",
    "SLOReport",
    "TraceEvent",
    "aggregate_goodput",
    "dump_trace",
    "load_trace",
]
