"""repro.core — the paper's contribution: node-to-PU scheduling for
hybrid in-memory-computing inference engines, plus the IMCE simulator.
"""

from .cost import (
    CostModel,
    HardwareProfile,
    IMCE_DEFAULT,
    IMCE_FAST_LINK,
    PUSpec,
    make_pus,
)
from .graph import Graph, GraphError, MultiTenantGraph, Node, OpKind, PUType
from .metrics import NormalizedPoint, normalize, utilization_table
from .schedulers import (
    Assignment,
    ScheduleError,
    Scheduler,
    available,
    get_scheduler,
    schedule_replicated,
)
from .simulator import (
    IMCESimulator,
    MultiTenantSimulator,
    SimResult,
    TenantMetrics,
)

__all__ = [
    "CostModel",
    "HardwareProfile",
    "IMCE_DEFAULT",
    "IMCE_FAST_LINK",
    "PUSpec",
    "make_pus",
    "Graph",
    "GraphError",
    "MultiTenantGraph",
    "Node",
    "OpKind",
    "PUType",
    "NormalizedPoint",
    "normalize",
    "utilization_table",
    "Assignment",
    "ScheduleError",
    "Scheduler",
    "available",
    "get_scheduler",
    "schedule_replicated",
    "IMCESimulator",
    "MultiTenantSimulator",
    "SimResult",
    "TenantMetrics",
]
