"""Serving control plane: SLO-aware admission control, tenant churn and
replica autoscaling over the IMCE fleet.

The paper deploys a *fixed* set of CNN graphs; a production fleet faces
*changing* traffic — tenants arrive with service promises, depart, get
re-prioritized, and PUs fail and rejoin underneath them.  This module
is the deterministic, trace-driven control loop above the pieces the
earlier tiers provide:

* **admission control** — an arriving tenant (model graph + SLO: a
  minimum processing rate and/or a maximum streaming sojourn latency)
  is *probed* before it is admitted: the candidate co-schedule (union +
  newcomer, current replica widths) is placed by ``lblp-mt`` and
  measured in the discrete-event simulator; the tenant is admitted only
  if every admitted tenant's SLO — and its own — would still be met.
* **reclaim** — before rejecting, the plane retries the probe with all
  layer replicas reclaimed: elasticity spent on throughput for the
  already-admitted is returned when the capacity is needed to honor a
  new promise (autoscaling re-adds whatever still fits afterwards).
* **replica autoscaling** — free capacity is spent on the *hottest*
  admitted tenant (the one with least SLO headroom): its bottleneck
  layers are widened LRMP-style through the ``lblp-r`` probe sessions,
  with the transfer-aware analytic gain model pruning hopeless
  candidates before any simulation.
* **repair / eviction** — a PU failure (or reweight) can make the
  admitted set infeasible through no admission mistake; the plane then
  sheds the lightest-weight, most-recently-admitted tenants until every
  surviving promise holds again.  With repair on, *no admitted tenant
  ever samples a violated SLO* — violations only appear in the reports
  of baselines that skip admission (``admission=False``).

Everything is deterministic: the same trace and fleet produce a
bit-identical decision log and SLO reports per simulation engine
(``tests/test_serving.py`` pins this), so the log is an audit trail,
not a telemetry sample.  The loop stays incremental through the cache
layers underneath: replica probes share one derived graph + inner
schedule + seeded ``SimContext`` per replica signature
(``Graph.scratch`` probe sessions), repeated visits to a serving state
hit the content-keyed run memo, and tenant churn invalidates exactly
the union-derived caches (``ElasticSession._tenant_churn``).

Trace file format
-----------------
A trace is a JSON array of event objects, one per control tick::

    [{"kind": "arrive", "tenant": "cam-0", "model": "resnet8",
      "slo": {"min_rate": 400.0, "max_latency": 0.25}, "weight": 1.0},
     {"kind": "load",   "tenant": "cam-0", "weight": 2.0},
     {"kind": "fail",   "pu_id": 3},
     {"kind": "join",   "pu_id": 3, "pu_type": "imc", "speed": 1.0},
     {"kind": "depart", "tenant": "cam-0"}]

``kind`` is one of ``arrive`` / ``depart`` / ``load`` (weight change) /
``fail`` / ``join``.  ``model`` names an entry of the model registry
handed to :class:`ServingControlPlane`; ``slo`` may promise either or
both dimensions.  :func:`load_trace` / :func:`dump_trace` round-trip
the format.
"""

from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from . import make_simulator
from .cost import CostModel, PUSpec
from .elastic import ElasticSession
from .graph import Graph, GraphError, MultiTenantGraph, PUType
from .schedulers import get_scheduler
from .schedulers.lblp_r import ProbeSession, replication_candidates
from .simulator import SimResult, slo_headroom


@dataclass(frozen=True)
class SLO:
    """A tenant's service promise: a minimum steady-state processing
    rate [frames/s] and/or a maximum streaming sojourn latency [s]."""

    min_rate: Optional[float] = None
    max_latency: Optional[float] = None

    def headroom(self, rate: float, latency: float) -> float:
        """Signed relative margin of attained figures to this promise —
        the same formula as :meth:`TenantMetrics.slo_headroom`, for
        callers holding raw figures instead of a metrics object."""
        return slo_headroom(rate, latency, self.min_rate, self.max_latency)

    def to_dict(self) -> dict:
        return {k: v for k, v in asdict(self).items() if v is not None}

    @classmethod
    def from_dict(cls, raw: Optional[dict]) -> "SLO":
        raw = raw or {}
        return cls(min_rate=raw.get("min_rate"),
                   max_latency=raw.get("max_latency"))


@dataclass(frozen=True)
class TraceEvent:
    """One tick of the serving trace (see module docstring)."""

    kind: str                       # arrive | depart | load | fail | join
    tenant: Optional[str] = None
    model: Optional[str] = None     # arrive: model-registry key
    slo: SLO = SLO()
    weight: float = 1.0             # arrive / load: serving weight
    pu_id: Optional[int] = None     # fail / join
    pu_type: Optional[str] = None   # join: "imc" | "dpu"
    speed: float = 1.0              # join

    def label(self) -> str:
        tgt = self.tenant if self.tenant is not None else self.pu_id
        return f"{self.kind}({tgt})"

    def to_dict(self) -> dict:
        out: dict = {"kind": self.kind}
        if self.tenant is not None:
            out["tenant"] = self.tenant
        if self.model is not None:
            out["model"] = self.model
        if self.slo != SLO():
            out["slo"] = self.slo.to_dict()
        if self.kind in ("arrive", "load"):
            out["weight"] = self.weight
        if self.pu_id is not None:
            out["pu_id"] = self.pu_id
        if self.pu_type is not None:
            out["pu_type"] = self.pu_type
        if self.kind == "join":
            out["speed"] = self.speed
        return out


def load_trace(text: str) -> List[TraceEvent]:
    """Parse the JSON trace format into :class:`TraceEvent` objects."""
    events = []
    for raw in json.loads(text):
        events.append(TraceEvent(
            kind=raw["kind"],
            tenant=raw.get("tenant"),
            model=raw.get("model"),
            slo=SLO.from_dict(raw.get("slo")),
            weight=raw.get("weight", 1.0),
            pu_id=raw.get("pu_id"),
            pu_type=raw.get("pu_type"),
            speed=raw.get("speed", 1.0),
        ))
    return events


def dump_trace(events: Sequence[TraceEvent]) -> str:
    return json.dumps([e.to_dict() for e in events], indent=2)


@dataclass
class Decision:
    """One auditable control-plane action.  A single trace event can
    yield several decisions (e.g. ``reclaim`` then ``admit`` then
    ``replicate``); ``index`` ties them back to the trace tick."""

    index: int                      # trace event index
    event: str                      # TraceEvent.label() of the trigger
    action: str                     # admit | reject | depart | load |
                                    # fail | join | replicate | reclaim |
                                    # evict
    tenant: Optional[str]
    reason: str
    admitted: List[str]             # tenant set after the action
    replicas: Dict[int, int]        # replica widths after the action
    rates: Dict[str, float]         # per-tenant attained rate [fps]
    latencies: Dict[str, float]     # per-tenant sojourn latency [s]
    headroom: Dict[str, float]      # per-tenant SLO headroom (signed)

    def to_dict(self) -> dict:
        d = asdict(self)
        d["replicas"] = {str(k): v for k, v in self.replicas.items()}
        # strict JSON: an unbounded headroom (nothing promised) is null,
        # never the non-standard Infinity token
        d["headroom"] = {t: (None if math.isinf(h) else h)
                         for t, h in self.headroom.items()}
        return d


@dataclass
class SLOReport:
    """Per-tenant audit: the promise, what was attained at every trace
    tick the tenant was admitted for, and the violation intervals."""

    tenant: str
    slo: SLO
    weight: float
    admitted_index: Optional[int] = None
    departed_index: Optional[int] = None
    rejected_index: Optional[int] = None
    evicted_index: Optional[int] = None
    #: (trace index, attained rate, attained latency, SLO headroom)
    samples: List[Tuple[int, float, float, float]] = field(default_factory=list)

    @property
    def violations(self) -> List[Tuple[int, int]]:
        """Inclusive trace-index intervals where the SLO was broken."""
        out: List[Tuple[int, int]] = []
        for idx, _r, _l, h in self.samples:
            if h >= 0.0:
                continue
            if out and out[-1][1] == idx - 1:
                out[-1] = (out[-1][0], idx)
            else:
                out.append((idx, idx))
        return out

    def satisfied(self) -> bool:
        """True iff the tenant was admitted and never sampled below its
        promise while resident."""
        return self.admitted_index is not None and not self.violations

    def to_dict(self) -> dict:
        d = asdict(self)
        d["slo"] = self.slo.to_dict()
        # strict JSON: clamp unbounded headrooms (see Decision.to_dict)
        d["samples"] = [[i, r, lat, None if math.isinf(h) else h]
                        for (i, r, lat, h) in self.samples]
        d["violations"] = [list(v) for v in self.violations]
        d["satisfied"] = self.satisfied()
        return d


def aggregate_goodput(reports: Dict[str, SLOReport],
                      n_events: int) -> Tuple[List[float], float]:
    """Per-trace-tick goodput and its mean over the whole trace.

    Goodput counts a tenant's attained rate only while its SLO holds: a
    violated promise delivers zero value to its owner, which is what
    separates SLO-aware admission from admit-all over-subscription."""
    per_tick = [0.0] * n_events
    for rep in reports.values():
        for idx, rate, _lat, h in rep.samples:
            if h >= 0.0:
                per_tick[idx] += rate
    mean = sum(per_tick) / n_events if n_events else 0.0
    return per_tick, mean


class ServingControlPlane:
    """Trace-driven SLO-aware serving loop over one PU fleet.

    Parameters
    ----------
    pus:        the initial fleet.
    models:     model registry: ``arrive`` events reference graphs by
                key.  Graph objects may be shared across planes — they
                are never mutated (the union ingests copies of their
                node data).
    engine:     simulation engine for every probe and measurement
                (``"periodic"`` recommended: the control loop is
                exactly the cheap-what-if regime it was built for).
    frames:     per-stream frame budget of each measurement.
    admission:  gate arrivals on the SLO probe (False = admit-all
                baseline; violations then show up in the reports).
    autoscale:  spend free capacity on replica widening.
    replica_budget: max extra replicas resident at once (None -> fleet
                size, matching ``lblp-r``).
    min_headroom: required relative SLO margin for admission and
                autoscale acceptance (0.0 = meet exactly).
    """

    #: bottleneck-layer candidates probed per autoscale pass
    AUTOSCALE_CANDIDATES = 4

    def __init__(self, pus: Sequence[PUSpec], models: Dict[str, Graph],
                 cost_model: Optional[CostModel] = None,
                 engine: str = "periodic", frames: int = 64,
                 admission: bool = True, autoscale: bool = True,
                 replica_budget: Optional[int] = None,
                 min_headroom: float = 0.0) -> None:
        self.models = dict(models)
        self.cm = cost_model or CostModel()
        self.engine = engine
        self.frames = frames
        self.admission = admission
        self.autoscale = autoscale
        self.replica_budget = replica_budget
        self.min_headroom = min_headroom
        self.union = MultiTenantGraph("serving")
        self.session = ElasticSession(
            self.union, pus, algorithm="lblp-mt", cost_model=self.cm,
            engine=engine, frames=frames)
        self.slos: Dict[str, SLO] = {}
        self.weights: Dict[str, float] = {}
        self.replicas: Dict[int, int] = {}
        self.decisions: List[Decision] = []
        self.reports: Dict[str, SLOReport] = {}
        self.n_events = 0
        #: what-if schedule+simulate probes issued (admission + autoscale)
        self.probes = 0

    # -- trace playback ---------------------------------------------------
    def play(self, trace: Sequence[TraceEvent]) -> List[Decision]:
        for ev in trace:
            self.step(ev)
        return self.decisions

    def step(self, ev: TraceEvent) -> None:
        index = self.n_events
        self.n_events += 1
        handler = {
            "arrive": self._on_arrive, "depart": self._on_depart,
            "load": self._on_load, "fail": self._on_fail,
            "join": self._on_join,
        }.get(ev.kind)
        if handler is None:
            raise ValueError(f"unknown trace event kind {ev.kind!r}")
        handler(index, ev)
        self._sample(index)

    # -- event handlers ---------------------------------------------------
    def _on_arrive(self, index: int, ev: TraceEvent) -> None:
        tenant, model = ev.tenant, ev.model
        if tenant is None or model is None:
            raise ValueError("arrive events need tenant and model")
        if tenant in self.reports:
            raise GraphError(f"tenant name '{tenant}' already used")
        g = self.models[model]
        rep = self.reports[tenant] = SLOReport(
            tenant=tenant, slo=ev.slo, weight=ev.weight)
        if not self.admission:
            self._commit_arrival(index, ev, g,
                                 reason="admission control disabled")
            return
        # one candidate union serves both probes (shared probe session,
        # shared compiled context)
        cand = self._candidate_union(g, tenant, ev.weight)
        # probe 1: candidate union under the current replica widths
        res = self._probe_arrival(g, tenant, ev.weight, self.replicas,
                                  cand=cand)
        heads = self._headrooms(res, extra={tenant: ev.slo})
        if self._feasible(heads):
            self._commit_arrival(index, ev, g,
                                 reason=self._headroom_reason(heads),
                                 cand=cand)
            return
        if self.replicas:
            # probe 2: reclaim every replica to make room
            res2 = self._probe_arrival(g, tenant, ev.weight, {}, cand=cand)
            heads2 = self._headrooms(res2, extra={tenant: ev.slo})
            if self._feasible(heads2):
                self.replicas = {}
                self._decide(index, ev, "reclaim", None,
                             "replicas reclaimed to admit "
                             f"'{tenant}'")
                self._commit_arrival(index, ev, g,
                                     reason=self._headroom_reason(heads2),
                                     cand=cand)
                return
            heads = heads2
        rep.rejected_index = index
        self._decide(index, ev, "reject", tenant,
                     "would break SLOs: " + self._headroom_reason(heads))

    def _commit_arrival(self, index: int, ev: TraceEvent, g: Graph,
                        reason: str,
                        cand: Optional[MultiTenantGraph] = None) -> None:
        tenant = ev.tenant
        if cand is not None:
            # commit the probed candidate itself: its probe session and
            # content-keyed run memo make the re-measurement free
            self.session.adopt_union(cand, recovery="tenant-add",
                                     tenant=tenant, replicas=self.replicas)
            self.union = cand
        else:
            self.session.add_tenant(g, tenant, weight=ev.weight,
                                    replicas=self.replicas)
        self.slos[tenant] = ev.slo
        self.weights[tenant] = ev.weight
        self.reports[tenant].admitted_index = index
        self._reconcile(index, ev)
        self._decide(index, ev, "admit", tenant, reason)
        self._autoscale(index, ev)

    def _on_depart(self, index: int, ev: TraceEvent) -> None:
        tenant = self._resident(index, ev)
        if tenant is None:
            return
        self.session.remove_tenant(tenant, replicas=self.replicas)
        self.slos.pop(tenant)
        self.weights.pop(tenant)
        self.reports[tenant].departed_index = index
        self._reconcile(index, ev)
        self._decide(index, ev, "depart", tenant, "tenant departed")
        self._repair(index, ev)
        self._autoscale(index, ev)

    def _on_load(self, index: int, ev: TraceEvent) -> None:
        tenant = self._resident(index, ev)
        if tenant is None:
            return
        self.session.reweight(tenant, ev.weight, replicas=self.replicas)
        self.weights[tenant] = ev.weight
        self.reports[tenant].weight = ev.weight
        self._reconcile(index, ev)
        self._decide(index, ev, "load", tenant,
                     f"serving weight -> {ev.weight:g}")
        self._repair(index, ev)
        self._autoscale(index, ev)

    def _on_fail(self, index: int, ev: TraceEvent) -> None:
        e = self.session.fail(ev.pu_id)
        # a replica-absorb recovery narrowed groups under us
        self.replicas = self.session.replica_counts()
        self._reconcile(index, ev)
        self._decide(index, ev, "fail", None,
                     f"PU {ev.pu_id} failed ({e.recovery})")
        self._repair(index, ev)
        self._autoscale(index, ev)

    def _on_join(self, index: int, ev: TraceEvent) -> None:
        if ev.pu_id is None or ev.pu_type is None:
            raise ValueError("join events need pu_id and pu_type")
        pu = PUSpec(pu_id=ev.pu_id, pu_type=PUType(ev.pu_type),
                    speed=ev.speed)
        self.session.join(pu, replicas=self.replicas)
        self._reconcile(index, ev)
        self._decide(index, ev, "join", None, f"PU {ev.pu_id} joined")
        self._repair(index, ev)
        self._autoscale(index, ev)

    def _resident(self, index: int, ev: TraceEvent) -> Optional[str]:
        """Traces are policy-independent: an event for a tenant this
        plane rejected (or already evicted) is a recorded no-op, so one
        trace replays identically against different policies."""
        t = ev.tenant
        if t in self.slos:
            return t
        self._decide(index, ev, "noop", t, f"'{t}' is not resident")
        return None

    # -- control actions --------------------------------------------------
    def _reconcile(self, index: int, ev: TraceEvent) -> None:
        """Bring the served schedule back to the desired replica widths
        after a structural event.  The churn verbs are handed the
        widths and schedule the replicated state directly, so this is
        normally a no-op check; it still fires after a full-reschedule
        failover (widths dropped) or when departures orphaned entries."""
        self.replicas = {b: k for b, k in self.replicas.items()
                         if b in self.union.nodes}
        if self.session.replica_counts() != self.replicas:
            self.session.set_replicas(self.replicas)

    def _repair(self, index: int, ev: TraceEvent) -> None:
        """Restore feasibility after capacity loss (see class doc):
        first return the elasticity — reclaim every replica, exactly
        like the admission path does before rejecting — and only then
        evict, lightest serving weight first, then most recently
        admitted, then name: the cheapest promises to break when
        capacity is lost through no admission mistake."""
        if not self.admission:
            return
        if (self.slos and self.replicas
                and not self._feasible(self._headrooms(self._result()))):
            self.replicas = {}
            self.session.set_replicas({}, recovery="reclaim")
            self._decide(index, ev, "reclaim", None,
                         "SLO repair: replicas reclaimed before eviction")
        while self.slos:
            heads = self._headrooms(self._result())
            if self._feasible(heads):
                return
            victim = min(
                self.slos,
                key=lambda t: (self.weights[t],
                               -self.reports[t].admitted_index, t))
            self.session.remove_tenant(victim)
            self.slos.pop(victim)
            self.weights.pop(victim)
            self.reports[victim].evicted_index = index
            self._reconcile(index, ev)
            self._decide(index, ev, "evict", victim,
                         "SLO repair: " + self._headroom_reason(heads))

    def _autoscale(self, index: int, ev: TraceEvent) -> None:
        """Spend free capacity on the hottest admitted tenant: widen its
        bottleneck layers while every SLO keeps its margin and the hot
        tenant's rate actually improves.  Candidates are pruned by the
        transfer-aware analytic gain model before any probe."""
        if not self.autoscale or not self.slos:
            return
        budget = (self.replica_budget if self.replica_budget is not None
                  else len(self.session.live))
        while sum(k - 1 for k in self.replicas.values()) < budget:
            res = self._result()
            heads = self._headrooms(res)
            hot = min(self.slos,
                      key=lambda t: (heads[t],
                                     -res.tenants[t].utilization_share, t))
            accepted = False
            for base, k_new in self._bottleneck_candidates(hot):
                try_counts = {**self.replicas, base: k_new}
                probe = self._evaluate(self.union, try_counts)
                heads2 = self._headrooms(probe)
                if (self._feasible(heads2)
                        and probe.tenants[hot].rate
                        > res.tenants[hot].rate * 1.001):
                    self.replicas = try_counts
                    self.session.set_replicas(try_counts)
                    self._decide(
                        index, ev, "replicate", hot,
                        f"widened node {base} -> {k_new} for hottest "
                        f"tenant '{hot}'")
                    accepted = True
                    break
            if not accepted:
                return

    def _bottleneck_candidates(self, tenant: str
                               ) -> List[Tuple[int, int]]:
        """The hottest tenant's bottleneck layers: its nodes on the PU
        carrying its largest per-frame load, enumerated by the same
        :func:`~repro.core.schedulers.lblp_r.replication_candidates`
        loop the lblp-r search uses (heaviest amortized first,
        sub-fleet width cap, ``estimated_gain`` pruning), capped at
        :data:`AUTOSCALE_CANDIDATES` probes."""
        a = self.session.assignment
        sg = self.session.serving_graph
        tload = a.tenant_load(sg, self.cm).get(tenant)
        if not tload:
            return []
        cands, _ = replication_candidates(
            sg, a, a.load(sg, self.cm), self.cm, self.session.live,
            self.replicas,
            pu=max(tload, key=lambda p: (tload[p], -p)),
            node_filter=lambda n: n.meta.get("tenant") == tenant,
            limit=self.AUTOSCALE_CANDIDATES)
        return cands

    # -- probes / evaluation ----------------------------------------------
    def _candidate_union(self, g: Graph, tenant: str,
                         weight: float) -> MultiTenantGraph:
        cand = self.union.copy()
        cand.add_tenant(g, tenant)
        if weight != 1.0:
            cand.set_tenant_weight(tenant, weight)
        return cand

    def _probe_arrival(self, g: Graph, tenant: str, weight: float,
                       counts: Dict[int, int],
                       cand: Optional[MultiTenantGraph] = None) -> SimResult:
        """What-if: the union plus the candidate tenant under ``counts``
        replica widths, scheduled and measured without committing.
        Pass ``cand`` to probe one candidate union at several replica
        signatures (shared probe session and compiled context)."""
        if cand is None:
            cand = self._candidate_union(g, tenant, weight)
        return self._evaluate(cand, counts)

    def _evaluate(self, union: MultiTenantGraph,
                  counts: Dict[int, int]) -> SimResult:
        sched = get_scheduler(self.session.algorithm, self.cm)
        sess = ProbeSession.for_graph(union, self.cm, self.session.live,
                                       sched)
        e = sess.probe({b: k for b, k in counts.items() if k > 1})
        sim = make_simulator(e["graph"], self.cm, engine=self.engine)
        self.probes += 1
        return sim.run(e["assignment"], frames=self.frames)

    def _result(self) -> SimResult:
        res = self.session.history[-1].result
        if res is None:
            raise RuntimeError("no serving state to evaluate")
        return res

    def _headrooms(self, res: SimResult,
                   extra: Optional[Dict[str, SLO]] = None
                   ) -> Dict[str, float]:
        slos = dict(self.slos)
        if extra:
            slos.update(extra)
        return {t: res.tenants[t].slo_headroom(s.min_rate, s.max_latency)
                for t, s in slos.items() if t in res.tenants}

    def _feasible(self, heads: Dict[str, float]) -> bool:
        return all(h >= self.min_headroom for h in heads.values())

    @staticmethod
    def _headroom_reason(heads: Dict[str, float]) -> str:
        worst = sorted(heads.items(), key=lambda kv: kv[1])[:3]
        body = ", ".join(f"{t}={h:+.3f}" for t, h in worst)
        return f"min headroom [{body}]" if body else "no admitted tenants"

    # -- bookkeeping ------------------------------------------------------
    def _decide(self, index: int, ev: TraceEvent, action: str,
                tenant: Optional[str], reason: str) -> None:
        last = self.session.history[-1]
        res = last.result
        self.decisions.append(Decision(
            index=index,
            event=ev.label(),
            action=action,
            tenant=tenant,
            reason=reason,
            admitted=list(self.union.tenants),
            replicas=dict(self.replicas),
            rates=dict(last.tenant_rates or {}),
            latencies=dict(last.tenant_latencies or {}),
            headroom=self._headrooms(res) if res is not None else {},
        ))

    def _sample(self, index: int) -> None:
        if not self.slos:
            return
        res = self._result()
        for t, slo in self.slos.items():
            m = res.tenants[t]
            self.reports[t].samples.append(
                (index, m.rate, m.latency,
                 m.slo_headroom(slo.min_rate, slo.max_latency)))

    # -- audit artifacts --------------------------------------------------
    def decision_log(self) -> List[dict]:
        return [d.to_dict() for d in self.decisions]

    def slo_reports(self) -> Dict[str, dict]:
        return {t: r.to_dict() for t, r in sorted(self.reports.items())}

    def audit_json(self) -> str:
        """The full audit artifact, canonically serialized — equality of
        two of these is the determinism contract."""
        per_tick, mean = aggregate_goodput(self.reports, self.n_events)
        return json.dumps({
            "decisions": self.decision_log(),
            "reports": self.slo_reports(),
            "goodput_per_tick": per_tick,
            "goodput_mean": mean,
            "events": self.n_events,
            "probes": self.probes,
        }, indent=2, sort_keys=True)
