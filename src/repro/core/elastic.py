"""Elastic rescheduling: PU failure -> LBLP re-placement on survivors.

This is the paper's algorithm doing fleet-management duty: because LBLP
is fast (O(V log V + V*P)) and deterministic, the CDA can re-run it on
the surviving PU set the moment a PU drops, and reconfigure.  The same
policy drives the LM tier's stage re-partitioning when a device group is
lost (core.pipeline_partition).

``ElasticSession`` tracks the live fleet, produces assignments, and
reports the degradation curve (rate/latency after each failure) — see
benchmarks/elastic_bench.py and examples/elastic_reschedule.py.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .cost import CostModel, PUSpec
from .graph import Graph, MultiTenantGraph
from .schedulers import Assignment, get_scheduler
from .simulator import IMCESimulator, MultiTenantSimulator, SimResult


@dataclass
class ElasticEvent:
    failed_pu: Optional[int]
    n_pus: int
    rate: float
    latency: float
    mapping: Dict[int, int]
    #: per-tenant steady-state rates when the session serves a
    #: MultiTenantGraph — one PU failure re-co-schedules *all* tenants.
    tenant_rates: Optional[Dict[str, float]] = None
    tenant_latencies: Optional[Dict[str, float]] = None


class ElasticSession:
    """Maintains a live node->PU mapping under PU failures."""

    def __init__(self, graph: Graph, pus: Sequence[PUSpec],
                 algorithm: Optional[str] = None,
                 cost_model: Optional[CostModel] = None) -> None:
        self.g = graph
        self.cm = cost_model or CostModel()
        self._multi = isinstance(graph, MultiTenantGraph)
        self.algorithm = algorithm or ("lblp-mt" if self._multi else "lblp")
        self.live: List[PUSpec] = list(pus)
        self.history: List[ElasticEvent] = []
        self._schedule(None)

    # -- internals -------------------------------------------------------
    def _schedule(self, failed: Optional[int]) -> None:
        if not self.live:
            raise RuntimeError("no surviving PUs")
        sched = get_scheduler(self.algorithm, self.cm)
        self.assignment: Assignment = sched.schedule(self.g, self.live)
        sim_cls = MultiTenantSimulator if self._multi else IMCESimulator
        sim = sim_cls(self.g, self.cm)
        res: SimResult = sim.run(self.assignment, frames=64)
        self.history.append(ElasticEvent(
            failed_pu=failed,
            n_pus=len(self.live),
            rate=res.rate,
            latency=res.latency,
            mapping=dict(self.assignment.mapping),
            tenant_rates=({t: m.rate for t, m in res.tenants.items()}
                          if res.tenants else None),
            tenant_latencies=({t: m.latency for t, m in res.tenants.items()}
                              if res.tenants else None),
        ))

    # -- public API ------------------------------------------------------
    def fail(self, pu_id: int) -> ElasticEvent:
        """A PU died: reschedule everything it was running."""
        before = len(self.live)
        self.live = [p for p in self.live if p.pu_id != pu_id]
        if len(self.live) == before:
            raise KeyError(f"PU {pu_id} not in live set")
        # feasibility: at least one PU of each required type must survive
        self._schedule(failed=pu_id)
        return self.history[-1]

    def join(self, pu: PUSpec) -> ElasticEvent:
        """A PU (re)joined the fleet: scale back up."""
        self.live.append(pu)
        self._schedule(failed=None)
        return self.history[-1]

    def degradation_curve(self) -> List[Tuple[int, float, float]]:
        return [(e.n_pus, e.rate, e.latency) for e in self.history]
