"""Elastic rescheduling: PU failure -> LBLP re-placement on survivors.

This is the paper's algorithm doing fleet-management duty: because LBLP
is fast (O(V log V + V*P)) and deterministic, the CDA can re-run it on
the surviving PU set the moment a PU drops, and reconfigure.  The same
policy drives the LM tier's stage re-partitioning when a device group is
lost (core.pipeline_partition).

Replica absorption (LRMP-style fast path)
-----------------------------------------
When the serving schedule carries layer replicas (``lblp-r``), a failed
PU whose every node is a replica with a surviving sibling does not need
a re-schedule at all: the dropped replicas' frames simply re-divide
round-robin over the survivors (``Graph.drop_replica``), the rest of
the mapping is untouched, and the fleet keeps serving at the amortized
degraded rate.  Only when a sole copy of some node dies does the
session fall back to a full re-schedule.  ``ElasticEvent.recovery``
records which path ran.

``ElasticSession`` tracks the live fleet, produces assignments, and
reports the degradation curve (rate/latency after each failure) — see
benchmarks/elastic_bench.py and examples/elastic_reschedule.py.

Serving verbs (tenant churn)
----------------------------
On a :class:`~repro.core.graph.MultiTenantGraph`-backed session the
tenant set is no longer fixed at construction: ``add_tenant`` /
``remove_tenant`` mutate the union in place and re-co-schedule,
``reweight`` changes a tenant's serving priority (policy, not
structure: compiled contexts survive, the run memos key weights by
content), and ``set_replicas`` serves the union at explicit replica
widths through the ``lblp-r`` probe session.  Churn drops exactly the
session caches derived from the union (``_tenant_churn``) — the
serving control plane (``repro.core.serving``) drives all of this
from a trace.

Simulation engine reuse
-----------------------
Every elastic event re-measures the fleet in the discrete-event
simulator.  The session holds one simulator per serving graph and the
compiled :class:`~repro.core.simcontext.SimContext` (topo order, bottom
levels, adjacency, phase tables) is cached on the graph itself, so
repeated events over the same serving graph — the common case: every
``join``/reschedule serves the original graph object — re-derive
nothing.  ``engine`` selects the measurement engine (``"exact"``
default; benchmarks pass ``"periodic"`` for the quantized early-exit
loop, see ``repro.core.simulator``).

The incremental-probe layer compounds here: the scheduler's longest
paths are cached on the serving graph (``Graph.scratch``), replica
graphs produced by the absorb fast path seed their compiled context
from the pre-failure graph's (``drop_replica`` preserves bottom levels
and cost rows — see ``core.simcontext``), and ``run()`` results are
content-memoized per context, so a fleet that oscillates between
compositions (fail -> join -> fail of the same PU) re-measures known
states for free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from . import make_simulator
from .cost import CostModel, PUSpec
from .graph import Graph, MultiTenantGraph
from .schedulers import Assignment, get_scheduler
from .simulator import SimResult


@dataclass
class ElasticEvent:
    failed_pu: Optional[int]
    n_pus: int
    rate: float
    latency: float
    mapping: Dict[int, int]
    #: per-tenant steady-state rates when the session serves a
    #: MultiTenantGraph — one PU failure re-co-schedules *all* tenants.
    tenant_rates: Optional[Dict[str, float]] = None
    tenant_latencies: Optional[Dict[str, float]] = None
    #: what triggered the re-placement: "schedule" (PU fail/join re-run
    #: of the scheduler), "replica-absorb" (surviving replicas soaked up
    #: the failed PU), or the serving-tier verbs "tenant-add" /
    #: "tenant-remove" / "reweight" / "replicate" / "reclaim"
    recovery: str = "schedule"
    #: tenant the event concerned, for churn/reweight events
    tenant: Optional[str] = None
    #: the full simulator result behind rate/latency — retained on the
    #: *most recent* event only (older entries are thinned to the
    #: scalar fields above, or the append-only history would pin every
    #: busy-interval list ever measured); None over an empty union
    result: Optional[SimResult] = None


class ElasticSession:
    """Maintains a live node->PU mapping under PU failures."""

    def __init__(self, graph: Graph, pus: Sequence[PUSpec],
                 algorithm: Optional[str] = None,
                 cost_model: Optional[CostModel] = None,
                 engine: str = "exact", frames: int = 64) -> None:
        self.g = graph
        self.cm = cost_model or CostModel()
        self._multi = isinstance(graph, MultiTenantGraph)
        self.algorithm = algorithm or ("lblp-mt" if self._multi else "lblp")
        self.engine = engine
        #: frame budget of the per-event measurement runs
        self.frames = frames
        self.live: List[PUSpec] = list(pus)
        self.history: List[ElasticEvent] = []
        # one simulator per serving graph; its compiled SimContext is
        # additionally cached on the graph, so neither is rebuilt per event
        self._sims: Dict[int, tuple] = {}
        self._schedule(None)

    # -- internals -------------------------------------------------------
    def _schedule(self, failed: Optional[int], recovery: str = "schedule",
                  tenant: Optional[str] = None) -> None:
        if not self.live:
            raise RuntimeError("no surviving PUs")
        if not self.g.nodes:
            # an all-departed union: the fleet idles, nothing to place
            # or simulate (a session may be born empty and grow by
            # add_tenant, or churn down to zero tenants)
            self.serving_graph = self.g
            self.assignment = Assignment(
                mapping={}, pus=list(self.live), algorithm=self.algorithm)
            if self.history:
                self.history[-1].result = None   # see ElasticEvent.result
            self.history.append(ElasticEvent(
                failed_pu=failed, n_pus=len(self.live), rate=0.0,
                latency=0.0, mapping={},
                tenant_rates={} if self._multi else None,
                tenant_latencies={} if self._multi else None,
                recovery=recovery, tenant=tenant))
            return
        sched = get_scheduler(self.algorithm, self.cm)
        a: Assignment = sched.schedule(self.g, self.live)
        # graph-transforming schedulers (lblp-r) serve a derived graph
        serving = a.meta.get("replicated_graph", self.g)
        self._record(failed, serving, a, recovery=recovery, tenant=tenant)

    def _sim_for(self, serving: Graph):
        hit = self._sims.get(id(serving))
        if hit is not None and hit[0] is serving:
            return hit[1]
        if len(self._sims) >= 8:
            self._sims.clear()
        sim = make_simulator(serving, self.cm, engine=self.engine)
        self._sims[id(serving)] = (serving, sim)
        return sim

    def _record(self, failed: Optional[int], serving: Graph,
                a: Assignment, recovery: str,
                tenant: Optional[str] = None) -> None:
        self.serving_graph: Graph = serving
        self.assignment = a
        res: SimResult = self._sim_for(serving).run(a, frames=self.frames)
        if self.history:
            self.history[-1].result = None   # see ElasticEvent.result
        self.history.append(ElasticEvent(
            failed_pu=failed,
            n_pus=len(self.live),
            rate=res.rate,
            latency=res.latency,
            mapping=dict(a.mapping),
            tenant_rates=({t: m.rate for t, m in res.tenants.items()}
                          if res.tenants else None),
            tenant_latencies=({t: m.latency for t, m in res.tenants.items()}
                              if res.tenants else None),
            recovery=recovery,
            tenant=tenant,
            result=res,
        ))

    def _absorb(self, pu_id: int) -> bool:
        """Replica fast path: if every node on the failed PU is a replica
        with a surviving sibling, drop those replicas (their frames
        re-divide round-robin over the siblings) and keep the rest of the
        mapping untouched — no scheduler run."""
        a, g = self.assignment, self.serving_graph
        victims = [nid for nid, pid in a.mapping.items() if pid == pu_id]
        if not victims:
            return False
        groups = g.replica_groups()
        victim_set = set(victims)
        for nid in victims:
            grp = g.nodes[nid].replica_group
            if grp is None:
                return False
            if not any(m not in victim_set for m in groups[grp]):
                return False  # the whole group died with the PU
        g2 = g
        for nid in victims:
            g2 = g2.drop_replica(nid)
        survivors = [p for p in a.pus if p.pu_id != pu_id]
        new_a = Assignment(
            mapping={n: p for n, p in a.mapping.items() if n not in victim_set},
            pus=survivors,
            algorithm=a.algorithm,
            meta={**a.meta, "replicated_graph": g2,
                  "replicas": {b: len(ms)
                               for b, ms in g2.replica_groups().items()},
                  "absorbed_pu": pu_id, "dropped_replicas": sorted(victims)},
        )
        # the survivors' amortized load rose: refresh the derived figures
        # copied from the pre-failure schedule
        new_a.meta["bound_interval"] = max(new_a.load(g2, self.cm).values())
        new_a.meta["extra_replicas"] = sum(
            len(ms) - 1 for ms in g2.replica_groups().values())
        self._record(pu_id, g2, new_a, recovery="replica-absorb")
        return True

    # -- public API ------------------------------------------------------
    def fail(self, pu_id: int) -> ElasticEvent:
        """A PU died: absorb its load into surviving replicas if possible,
        otherwise reschedule everything it was running."""
        before = len(self.live)
        self.live = [p for p in self.live if p.pu_id != pu_id]
        if len(self.live) == before:
            raise KeyError(f"PU {pu_id} not in live set")
        if not self._absorb(pu_id):
            self._schedule(failed=pu_id)
        return self.history[-1]

    def join(self, pu: PUSpec,
             replicas: Optional[Dict[int, int]] = None) -> ElasticEvent:
        """A PU (re)joined the fleet: scale back up.  ``replicas``
        optionally re-applies replica widths in the same pass."""
        if any(p.pu_id == pu.pu_id for p in self.live):
            # all load/mapping accounting keys by pu_id; a duplicate
            # would silently double-book one physical unit
            raise KeyError(f"PU {pu.pu_id} is already in the live set")
        self.live.append(pu)
        if replicas and self.g.nodes:
            self._reschedule(replicas, recovery="schedule", tenant=None)
        else:
            self._schedule(failed=None)
        return self.history[-1]

    # -- tenant churn (serving tier) --------------------------------------
    def _union(self) -> MultiTenantGraph:
        if not self._multi:
            raise TypeError(
                "tenant churn needs a MultiTenantGraph-backed session")
        return self.g  # type: ignore[return-value]

    def _tenant_churn(self) -> None:
        """The union graph just mutated (tenant added/removed): drop
        exactly the session caches derived from it — the simulator held
        for the union itself and the ones for replica variants seeded
        from it.  Holding onto them is the stale-cache bug this guards
        against: ``_sim_for`` keys by graph *identity*, so after an
        in-place mutation it would keep handing back a simulator whose
        compiled context (and ``measured_rate``/``run`` memos) describe
        the pre-churn tenant set.  Graph-level caches (contexts,
        scratch, probe sessions) were already invalidated by
        ``Graph._invalidate`` inside the mutation."""
        self._sims = {
            k: v for k, v in self._sims.items()
            if v[0] is not self.g and v[0].ctx_seed() is not self.g
        }

    def add_tenant(self, graph: Graph, tenant: Optional[str] = None,
                   weight: float = 1.0,
                   replicas: Optional[Dict[int, int]] = None) -> ElasticEvent:
        """A tenant arrived: ingest its model graph into the served
        union (under serving weight ``weight``) and re-co-schedule.
        ``replicas`` optionally carries the replica widths to serve the
        new union at, so the replicated state is scheduled and measured
        directly instead of via a bare-union intermediate."""
        mt = self._union()
        t = mt.add_tenant(graph, tenant)
        if weight != 1.0:
            mt.set_tenant_weight(t, weight)
        self._tenant_churn()
        self._reschedule(replicas, recovery="tenant-add", tenant=t)
        return self.history[-1]

    def remove_tenant(self, tenant: str,
                      replicas: Optional[Dict[int, int]] = None
                      ) -> ElasticEvent:
        """A tenant departed: drop its component (and any replicas of
        its nodes) from the union and re-co-schedule the rest.
        ``replicas`` entries for departed nodes are filtered here."""
        mt = self._union()
        mt.remove_tenant(tenant)
        self._tenant_churn()
        self._reschedule(replicas, recovery="tenant-remove", tenant=tenant)
        return self.history[-1]

    def reweight(self, tenant: str, weight: float,
                 replicas: Optional[Dict[int, int]] = None) -> ElasticEvent:
        """Change a tenant's serving weight and re-co-schedule.  Weights
        are policy, not structure: compiled contexts and cached
        simulators stay valid (schedule and run memos key the weights
        by content), so this is the cheapest of the churn events."""
        mt = self._union()
        mt.set_tenant_weight(tenant, weight)
        self._reschedule(replicas, recovery="reweight", tenant=tenant)
        return self.history[-1]

    def adopt_union(self, union: MultiTenantGraph,
                    recovery: str = "tenant-add",
                    tenant: Optional[str] = None,
                    replicas: Optional[Dict[int, int]] = None
                    ) -> ElasticEvent:
        """Swap in an externally prepared union — e.g. an admission
        probe's candidate, content-identical to the served union plus
        the newcomer — as the served graph.  Unlike :meth:`add_tenant`
        this keeps the prepared graph's caches (compiled contexts,
        probe sessions, content-keyed run memos), so committing an
        already-probed state re-measures nothing."""
        if not isinstance(union, MultiTenantGraph):
            raise TypeError("adopt_union needs a MultiTenantGraph")
        self.g = union
        self._multi = True
        # every cached simulator belongs to the previous union's lineage
        self._sims.clear()
        self._reschedule(replicas, recovery=recovery, tenant=tenant)
        return self.history[-1]

    def _reschedule(self, replicas: Optional[Dict[int, int]],
                    recovery: str, tenant: Optional[str]) -> None:
        """Churn-verb scheduling: replicated when widths were handed in
        (and any survive the mutation), plain otherwise."""
        if replicas:
            replicas = {b: k for b, k in replicas.items()
                        if k > 1 and b in self.g.nodes}
        if replicas:
            self._schedule_replicated(replicas, recovery, tenant)
        else:
            self._schedule(None, recovery=recovery, tenant=tenant)

    # -- replica control (serving tier) -----------------------------------
    def set_replicas(self, counts: Dict[int, int],
                     recovery: str = "replicate") -> ElasticEvent:
        """Serve the union with the given replica widths (base node id
        -> total count; entries of 1 are no-ops, ``{}`` reclaims every
        replica).  Runs through the ``lblp-r`` probe session cached on
        the union, so repeated visits to one replica signature — the
        serving control loop's common case — share a single derived
        graph, inner schedule, seeded simulation context and run memo."""
        self._schedule_replicated(
            {b: k for b, k in counts.items() if k > 1}, recovery, None)
        return self.history[-1]

    def _schedule_replicated(self, counts: Dict[int, int], recovery: str,
                             tenant: Optional[str]) -> None:
        if self.algorithm == "lblp-r":
            raise ValueError(
                "set_replicas drives replication explicitly; use an inner "
                "algorithm (lblp/lblp-mt) for the session, not lblp-r")
        from .schedulers.lblp_r import ProbeSession
        sched = get_scheduler(self.algorithm, self.cm)
        sess = ProbeSession.for_graph(self.g, self.cm, self.live, sched)
        e = sess.probe(counts)
        serving, inner_a = e["graph"], e["assignment"]
        # fresh Assignment: probe entries are shared cache objects
        a = Assignment(
            mapping=dict(inner_a.mapping),
            pus=list(self.live),
            algorithm=inner_a.algorithm,
            meta={**inner_a.meta,
                  "replicas": dict(counts),
                  "replicated_graph": serving,
                  "extra_replicas": sum(k - 1 for k in counts.values()),
                  "bound_interval": (max(e["load"].values())
                                     if e["load"] else 0.0)},
        )
        self._record(None, serving, a, recovery=recovery, tenant=tenant)

    def replica_counts(self) -> Dict[int, int]:
        """Replica widths of the currently served graph (base node id ->
        count), as maintained by set_replicas / lblp-r / absorb events."""
        return {b: len(ms)
                for b, ms in self.serving_graph.replica_groups().items()}

    def degradation_curve(self) -> List[Tuple[int, float, float]]:
        return [(e.n_pus, e.rate, e.latency) for e in self.history]
