"""Elastic rescheduling: PU failure -> LBLP re-placement on survivors.

This is the paper's algorithm doing fleet-management duty: because LBLP
is fast (O(V log V + V*P)) and deterministic, the CDA can re-run it on
the surviving PU set the moment a PU drops, and reconfigure.  The same
policy drives the LM tier's stage re-partitioning when a device group is
lost (core.pipeline_partition).

Replica absorption (LRMP-style fast path)
-----------------------------------------
When the serving schedule carries layer replicas (``lblp-r``), a failed
PU whose every node is a replica with a surviving sibling does not need
a re-schedule at all: the dropped replicas' frames simply re-divide
round-robin over the survivors (``Graph.drop_replica``), the rest of
the mapping is untouched, and the fleet keeps serving at the amortized
degraded rate.  Only when a sole copy of some node dies does the
session fall back to a full re-schedule.  ``ElasticEvent.recovery``
records which path ran.

``ElasticSession`` tracks the live fleet, produces assignments, and
reports the degradation curve (rate/latency after each failure) — see
benchmarks/elastic_bench.py and examples/elastic_reschedule.py.

Simulation engine reuse
-----------------------
Every elastic event re-measures the fleet in the discrete-event
simulator.  The session holds one simulator per serving graph and the
compiled :class:`~repro.core.simcontext.SimContext` (topo order, bottom
levels, adjacency, phase tables) is cached on the graph itself, so
repeated events over the same serving graph — the common case: every
``join``/reschedule serves the original graph object — re-derive
nothing.  ``engine`` selects the measurement engine (``"exact"``
default; benchmarks pass ``"periodic"`` for the quantized early-exit
loop, see ``repro.core.simulator``).

The incremental-probe layer compounds here: the scheduler's longest
paths are cached on the serving graph (``Graph.scratch``), replica
graphs produced by the absorb fast path seed their compiled context
from the pre-failure graph's (``drop_replica`` preserves bottom levels
and cost rows — see ``core.simcontext``), and ``run()`` results are
content-memoized per context, so a fleet that oscillates between
compositions (fail -> join -> fail of the same PU) re-measures known
states for free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from . import make_simulator
from .cost import CostModel, PUSpec
from .graph import Graph, MultiTenantGraph
from .schedulers import Assignment, get_scheduler
from .simulator import SimResult


@dataclass
class ElasticEvent:
    failed_pu: Optional[int]
    n_pus: int
    rate: float
    latency: float
    mapping: Dict[int, int]
    #: per-tenant steady-state rates when the session serves a
    #: MultiTenantGraph — one PU failure re-co-schedules *all* tenants.
    tenant_rates: Optional[Dict[str, float]] = None
    tenant_latencies: Optional[Dict[str, float]] = None
    #: how the fleet recovered: "schedule" (full re-run of the scheduler)
    #: or "replica-absorb" (surviving replicas soaked up the failed PU)
    recovery: str = "schedule"


class ElasticSession:
    """Maintains a live node->PU mapping under PU failures."""

    def __init__(self, graph: Graph, pus: Sequence[PUSpec],
                 algorithm: Optional[str] = None,
                 cost_model: Optional[CostModel] = None,
                 engine: str = "exact") -> None:
        self.g = graph
        self.cm = cost_model or CostModel()
        self._multi = isinstance(graph, MultiTenantGraph)
        self.algorithm = algorithm or ("lblp-mt" if self._multi else "lblp")
        self.engine = engine
        self.live: List[PUSpec] = list(pus)
        self.history: List[ElasticEvent] = []
        # one simulator per serving graph; its compiled SimContext is
        # additionally cached on the graph, so neither is rebuilt per event
        self._sims: Dict[int, tuple] = {}
        self._schedule(None)

    # -- internals -------------------------------------------------------
    def _schedule(self, failed: Optional[int]) -> None:
        if not self.live:
            raise RuntimeError("no surviving PUs")
        sched = get_scheduler(self.algorithm, self.cm)
        a: Assignment = sched.schedule(self.g, self.live)
        # graph-transforming schedulers (lblp-r) serve a derived graph
        serving = a.meta.get("replicated_graph", self.g)
        self._record(failed, serving, a, recovery="schedule")

    def _sim_for(self, serving: Graph):
        hit = self._sims.get(id(serving))
        if hit is not None and hit[0] is serving:
            return hit[1]
        if len(self._sims) >= 8:
            self._sims.clear()
        sim = make_simulator(serving, self.cm, engine=self.engine)
        self._sims[id(serving)] = (serving, sim)
        return sim

    def _record(self, failed: Optional[int], serving: Graph,
                a: Assignment, recovery: str) -> None:
        self.serving_graph: Graph = serving
        self.assignment = a
        res: SimResult = self._sim_for(serving).run(a, frames=64)
        self.history.append(ElasticEvent(
            failed_pu=failed,
            n_pus=len(self.live),
            rate=res.rate,
            latency=res.latency,
            mapping=dict(a.mapping),
            tenant_rates=({t: m.rate for t, m in res.tenants.items()}
                          if res.tenants else None),
            tenant_latencies=({t: m.latency for t, m in res.tenants.items()}
                              if res.tenants else None),
            recovery=recovery,
        ))

    def _absorb(self, pu_id: int) -> bool:
        """Replica fast path: if every node on the failed PU is a replica
        with a surviving sibling, drop those replicas (their frames
        re-divide round-robin over the siblings) and keep the rest of the
        mapping untouched — no scheduler run."""
        a, g = self.assignment, self.serving_graph
        victims = [nid for nid, pid in a.mapping.items() if pid == pu_id]
        if not victims:
            return False
        groups = g.replica_groups()
        victim_set = set(victims)
        for nid in victims:
            grp = g.nodes[nid].replica_group
            if grp is None:
                return False
            if not any(m not in victim_set for m in groups[grp]):
                return False  # the whole group died with the PU
        g2 = g
        for nid in victims:
            g2 = g2.drop_replica(nid)
        survivors = [p for p in a.pus if p.pu_id != pu_id]
        new_a = Assignment(
            mapping={n: p for n, p in a.mapping.items() if n not in victim_set},
            pus=survivors,
            algorithm=a.algorithm,
            meta={**a.meta, "replicated_graph": g2,
                  "replicas": {b: len(ms)
                               for b, ms in g2.replica_groups().items()},
                  "absorbed_pu": pu_id, "dropped_replicas": sorted(victims)},
        )
        # the survivors' amortized load rose: refresh the derived figures
        # copied from the pre-failure schedule
        new_a.meta["bound_interval"] = max(new_a.load(g2, self.cm).values())
        new_a.meta["extra_replicas"] = sum(
            len(ms) - 1 for ms in g2.replica_groups().values())
        self._record(pu_id, g2, new_a, recovery="replica-absorb")
        return True

    # -- public API ------------------------------------------------------
    def fail(self, pu_id: int) -> ElasticEvent:
        """A PU died: absorb its load into surviving replicas if possible,
        otherwise reschedule everything it was running."""
        before = len(self.live)
        self.live = [p for p in self.live if p.pu_id != pu_id]
        if len(self.live) == before:
            raise KeyError(f"PU {pu_id} not in live set")
        if not self._absorb(pu_id):
            self._schedule(failed=pu_id)
        return self.history[-1]

    def join(self, pu: PUSpec) -> ElasticEvent:
        """A PU (re)joined the fleet: scale back up."""
        self.live.append(pu)
        self._schedule(failed=None)
        return self.history[-1]

    def degradation_curve(self) -> List[Tuple[int, float, float]]:
        return [(e.n_pus, e.rate, e.latency) for e in self.history]
