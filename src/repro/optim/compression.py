"""Error-feedback INT8 gradient compression (distributed-optim trick).

Before the gradient all-reduce, each worker quantizes its gradient to
INT8 with a per-tensor scale and keeps the quantization residual in an
error-feedback buffer added to the next step's gradient (Seide et al.;
1-bit SGD lineage).  8x less all-reduce traffic on the collective-bound
term; error feedback preserves convergence (validated on the 100M
example + tests/test_runtime.py::TestCompression).

Pure-pytree implementation: ``compress`` -> (int8 tree, scales, new
error state); ``decompress`` reconstructs f32 grads.  The simulated
all-reduce in tests sums decompressed grads across workers.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: any          # pytree of f32 residuals (like grads)


def init(grads_like) -> EFState:
    return EFState(error=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress(grads, state: EFState) -> Tuple[any, any, EFState]:
    """Returns (q_tree int8, scale_tree f32 scalars, new_state)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        err = corrected - q.astype(jnp.float32) * scale
        return q, scale, err

    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    qs, scales, errs = [], [], []
    for g, e in zip(flat, flat_e):
        q, s, err = one(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(err)
    return (treedef.unflatten(qs), treedef.unflatten(scales),
            EFState(error=treedef.unflatten(errs)))


def decompress(q_tree, scale_tree):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)


def compressed_bytes(q_tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(q_tree))


def raw_bytes(grads) -> int:
    return sum(4 * x.size for x in jax.tree_util.tree_leaves(grads))
