"""AdamW + cosine schedule in pure JAX (no optax dependency).

State is a pytree mirroring params: m/v in float32 regardless of param
dtype (bf16 params, f32 moments — the standard large-model recipe).
Sharding: m/v inherit the param's sharding (same tree structure), so
FSDP-sharded params get FSDP-sharded optimizer state for free.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # () int32
    m: any                     # pytree like params (f32)
    v: any                     # pytree like params (f32)


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply(cfg: AdamWConfig, params, state: AdamWState, grads):
    """One AdamW update; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * g32 * g32
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr,
    }
