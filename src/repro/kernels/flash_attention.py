"""Pallas TPU kernel: flash attention (online-softmax, O(S) memory).

The LM-tier hot-spot.  Standard two-pass-free formulation: grid
(B*H, Sq/bq, Sk/bk) with the K axis innermost; running max/denominator/
accumulator live in VMEM scratch and the output tile is written in the
epilogue of the last K block.  Supports causal masking, sliding-window
(local) masking and gemma-style logit softcapping — the exact variants
the assigned architectures need.

q/k/v: (B, H, S, hd); blocks default (bq, bk) = (128, 128), hd padded to
the lane width by the caller if needed.  Validated in interpret mode
against ``ref.flash_attention_ref`` over shape/window/softcap sweeps.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], bq: int, bk: int, n_k: int,
                  s_valid: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0].astype(jnp.float32)            # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    d = q_pos - k_pos
    ok = k_pos < s_valid          # padded keys never win the softmax
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)               # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _epilogue():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom)[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, bq: int = 128,
                    bk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """(B, H, S, hd) -> (B, H, S, hd) f32."""
    B, H, S, hd = q.shape
    bq_, bk_ = min(bq, S), min(bk, S)
    pad = (-S) % bq_
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        qp = q
    padk = (-S) % bk_
    if padk:
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, padk), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, padk), (0, 0)))
    else:
        kp, vp = k, v
    Sq, Sk = qp.shape[2], kp.shape[2]
    n_k = Sk // bk_
    bh = B * H
    qp = qp.reshape(bh, Sq, hd)
    kp = kp.reshape(bh, Sk, hd)
    vp = vp.reshape(bh, Sk, hd)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
            window=window, softcap=softcap, bq=bq_, bk=bk_, n_k=n_k,
            s_valid=S),
        grid=(bh, Sq // bq_, n_k),
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, Sq, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(B, H, Sq, hd)[:, :, :S, :]
