"""Pallas TPU kernel: INT8 direct convolution (weight-stationary).

TPU-native adaptation of the paper's IMC conv nodes: the (K, K, Cin, bn)
filter block stays resident in VMEM (the crossbar analogue) while the
kernel sweeps the batch grid; the conv is computed as an unrolled
K x K tap accumulation of MXU matmuls over the full spatial map:

    out[i, j, co] = sum_{di, dj}  x[i*s+di, j*s+dj, :] @ w[di, dj, :, co]

Accumulation is INT32 (exact), with fused per-channel requantization in
the epilogue — bit-compatible with ``repro.models.quant.quantized_conv2d``.

Scope: SAME padding, stride 1/2, spatial maps that fit VMEM as one block
(the paper's CIFAR-scale workloads; 34x34x512 int8 = 0.6 MB).  Larger
maps (YOLO 640x640 early layers) use the jnp oracle / XLA conv — see
ops.py dispatch.

Grid: (B, Cout/bn); x block (1, Hp, Wp, Cin); w block (K, K, Cin, bn).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, sx_ref, sw_ref, b_ref, o_ref, *,
                 ksize: int, stride: int, h_out: int, w_out: int):
    x = x_ref[0].astype(jnp.int32)             # (Hp, Wp, Cin)
    acc = jnp.zeros((h_out * w_out, o_ref.shape[-1]), jnp.int32)
    for di in range(ksize):
        for dj in range(ksize):
            tap = jax.lax.slice(
                x,
                (di, dj, 0),
                (di + stride * (h_out - 1) + 1,
                 dj + stride * (w_out - 1) + 1,
                 x.shape[-1]),
                (stride, stride, 1),
            )                                   # (h_out, w_out, Cin)
            tap2d = tap.reshape(h_out * w_out, x.shape[-1])
            w_tap = w_ref[di, dj].astype(jnp.int32)   # (Cin, bn)
            acc += jax.lax.dot_general(
                tap2d, w_tap, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * sx_ref[0, 0] * sw_ref[0, :] + b_ref[0, :]
    o_ref[...] = y.reshape(1, h_out, w_out, -1)


@functools.partial(jax.jit,
                   static_argnames=("stride", "bn", "interpret"))
def imc_conv2d(qx: jnp.ndarray, qw: jnp.ndarray, sx: jnp.ndarray,
               sw: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
               *, stride: int = 1, bn: int = 128,
               interpret: bool = False) -> jnp.ndarray:
    """INT8 conv: x (B, H, W, Cin) int8, w (K, K, Cin, Cout) int8,
    SAME padding -> (B, H/s, W/s, Cout) f32."""
    B, H, W, Cin = qx.shape
    K, K2, Cin2, Cout = qw.shape
    assert K == K2 and Cin == Cin2
    h_out = -(-H // stride)
    w_out = -(-W // stride)
    # SAME padding (matches XLA for odd kernels)
    pad_h = max((h_out - 1) * stride + K - H, 0)
    pad_w = max((w_out - 1) * stride + K - W, 0)
    xp = jnp.pad(qx, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                      (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    bn_ = min(bn, Cout)
    rem = Cout % bn_
    wp = qw if rem == 0 else jnp.pad(qw, ((0, 0), (0, 0), (0, 0),
                                          (0, bn_ - rem)))
    swp = sw if rem == 0 else jnp.pad(sw, (0, bn_ - rem))
    bias = bias if bias is not None else jnp.zeros((Cout,), jnp.float32)
    bp = bias if rem == 0 else jnp.pad(bias, (0, bn_ - rem))
    Np = wp.shape[-1]
    Hp, Wp = xp.shape[1], xp.shape[2]

    out = pl.pallas_call(
        functools.partial(_conv_kernel, ksize=K, stride=stride,
                          h_out=h_out, w_out=w_out),
        grid=(B, Np // bn_),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, Cin), lambda b, n: (b, 0, 0, 0)),
            pl.BlockSpec((K, K, Cin, bn_), lambda b, n: (0, 0, 0, n)),
            pl.BlockSpec((1, 1), lambda b, n: (0, 0)),
            pl.BlockSpec((1, bn_), lambda b, n: (0, n)),
            pl.BlockSpec((1, bn_), lambda b, n: (0, n)),
        ],
        out_specs=pl.BlockSpec((1, h_out, w_out, bn_),
                               lambda b, n: (b, 0, 0, n)),
        out_shape=jax.ShapeDtypeStruct((B, h_out, w_out, Np), jnp.float32),
        interpret=interpret,
    )(xp, wp, jnp.asarray(sx, jnp.float32).reshape(1, 1),
      swp.reshape(1, -1).astype(jnp.float32), bp.reshape(1, -1))
    return out[..., :Cout]
