"""Pallas TPU kernels (+ pure-jnp oracles and jit'd wrappers).

imc_mvm          — INT8 weight-stationary matmul (IMC crossbar analogue)
conv2d           — INT8 direct conv, weight-stationary taps
flash_attention  — online-softmax attention (causal/window/softcap)
ops              — public dispatch wrappers (TPU native / CPU interpret)
ref              — oracles used by the tests and the CPU fallback
"""

from . import ops, ref
from .conv2d import imc_conv2d
from .flash_attention import flash_attention
from .imc_mvm import imc_mvm

__all__ = ["ops", "ref", "imc_conv2d", "flash_attention", "imc_mvm"]
