"""Pallas TPU kernel: INT8 weight-stationary matrix-vector/matrix multiply
— the TPU-native analogue of the paper's IMC crossbar.

Hardware adaptation (DESIGN.md §2): the IMC crossbar holds INT8 weights
stationary and streams activations through; on TPU the analogue is a
weight-stationary MXU matmul with INT8 operands and INT32 accumulation,
with the *weight block resident in VMEM across the whole M-grid sweep*
(the pallas grid iterates M-majored so the (K, N) weight tile is reused,
exactly like crossbar reuse).  Per-output-channel requantization
(acc * s_x * s_w[n] + bias) is fused into the kernel epilogue, matching
``repro.models.quant`` semantics bit-for-bit (integer part) so the
quantized CNN/MVM layers can swap implementations freely.

Grid: (M/bm, N/bn, K/bk) with K innermost (accumulate in a VMEM f32/i32
scratch); blocks default to MXU-aligned 128x128x128.

This container is CPU-only: tests run the kernel with interpret=True
(executes the same kernel body in Python) against the pure-jnp oracle in
``ref.py``; on real TPU the same pallas_call compiles to MXU code.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _imc_mvm_kernel(x_ref, w_ref, sx_ref, sw_ref, b_ref, o_ref, acc_ref,
                    *, n_k: int):
    """One (bm, bn) output tile; K-loop accumulated in i32 scratch.

    x_ref:  (bm, bk) int8    activations tile
    w_ref:  (bk, bn) int8    stationary weight tile
    sx_ref: (1, 1)   f32     per-tensor activation scale
    sw_ref: (1, bn)  f32     per-channel weight scales
    b_ref:  (1, bn)  f32     bias (folded BN)
    o_ref:  (bm, bn) f32     output tile
    acc_ref:(bm, bn) i32     VMEM accumulator scratch
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        o_ref[...] = acc * sx_ref[0, 0] * sw_ref[0, :] + b_ref[0, :]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def imc_mvm(qx: jnp.ndarray, qw: jnp.ndarray, sx: jnp.ndarray,
            sw: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
            *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
            bk: int = DEFAULT_BK, interpret: bool = False) -> jnp.ndarray:
    """Quantized matmul: (M, K) int8 x (K, N) int8 -> (M, N) f32.

    ``sx`` scalar f32; ``sw`` (N,) f32; ``bias`` (N,) f32 or None.
    M/K/N are padded to block multiples internally.
    """
    M, K = qx.shape
    K2, N = qw.shape
    assert K == K2, (qx.shape, qw.shape)
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)

    def pad_to(a, mult, axis):
        rem = a.shape[axis] % mult
        if rem == 0:
            return a
        pad = [(0, 0)] * a.ndim
        pad[axis] = (0, mult - rem)
        return jnp.pad(a, pad)

    xp = pad_to(pad_to(qx, bm_, 0), bk_, 1)
    wp = pad_to(pad_to(qw, bk_, 0), bn_, 1)
    swp = pad_to(sw.reshape(1, -1), bn_, 1)
    bp = pad_to((bias if bias is not None else
                 jnp.zeros((N,), jnp.float32)).reshape(1, -1), bn_, 1)
    Mp, Kp = xp.shape
    _, Np = wp.shape
    n_k = Kp // bk_

    out = pl.pallas_call(
        functools.partial(_imc_mvm_kernel, n_k=n_k),
        grid=(Mp // bm_, Np // bn_, n_k),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk_, bn_), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, 1), lambda m, n, k: (0, 0)),
            pl.BlockSpec((1, bn_), lambda m, n, k: (0, n)),
            pl.BlockSpec((1, bn_), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(xp, wp, jnp.asarray(sx, jnp.float32).reshape(1, 1), swp, bp)
    return out[:M, :N]
