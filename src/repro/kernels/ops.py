"""jit'd public wrappers around the Pallas kernels, with CPU dispatch.

On TPU the pallas kernels run natively; on CPU (this container, tests,
examples) they execute in interpret mode or fall back to the bit-exact
jnp oracle, so every caller can use one API everywhere.
"""

from __future__ import annotations


import jax

from . import ref
from .conv2d import imc_conv2d
from .flash_attention import flash_attention
from .imc_mvm import imc_mvm

#: spatial maps larger than this use the XLA conv (see conv2d.py scope)
_CONV_KERNEL_MAX_HW = 64


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantized_matmul(qx, qw, sx, sw, bias=None, *, interpret=None):
    """INT8 (M,K)x(K,N) -> f32, fused requant (IMC crossbar analogue)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return imc_mvm(qx, qw, sx, sw, bias, interpret=interpret)


def quantized_conv2d(qx, qw, sx, sw, bias=None, *, stride=1, interpret=None):
    """INT8 NHWC conv, SAME padding, fused requant."""
    if max(qx.shape[1], qx.shape[2]) > _CONV_KERNEL_MAX_HW:
        return ref.conv2d_ref(qx, qw, sx, sw, bias, stride=stride)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return imc_conv2d(qx, qw, sx, sw, bias, stride=stride,
                      interpret=interpret)


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              interpret=None):
    """Flash attention (B,H,S,hd) -> (B,H,S,hd) f32."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, interpret=interpret)
