"""Pure-jnp oracles for every Pallas kernel (bit-exact integer paths,
float-tolerance flash paths).  Tests assert kernels == these references
across shape/dtype sweeps in interpret mode."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def imc_mvm_ref(qx: jnp.ndarray, qw: jnp.ndarray, sx: jnp.ndarray,
                sw: jnp.ndarray, bias: Optional[jnp.ndarray] = None
                ) -> jnp.ndarray:
    """INT8 x INT8 -> INT32 -> requantized f32 (matches models.quant)."""
    acc = jax.lax.dot_general(
        qx.astype(jnp.int32), qw.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * jnp.asarray(sx, jnp.float32) \
        * sw.astype(jnp.float32)[None, :]
    if bias is not None:
        y = y + bias[None, :]
    return y


def conv2d_ref(qx: jnp.ndarray, qw: jnp.ndarray, sx: jnp.ndarray,
               sw: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
               stride: int = 1) -> jnp.ndarray:
    """INT8 NHWC/HWIO conv, SAME padding, integer accumulate, requant."""
    acc = jax.lax.conv_general_dilated(
        qx.astype(jnp.int32), qw.astype(jnp.int32),
        window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * jnp.asarray(sx, jnp.float32) \
        * sw.astype(jnp.float32)[None, None, None, :]
    if bias is not None:
        y = y + bias[None, None, None, :]
    return y


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jnp.ndarray:
    """Plain softmax attention; q/k/v (B, H, S, hd); f32 math."""
    B, H, S, hd = q.shape
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    idx = jnp.arange(S)
    d = idx[:, None] - idx[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    logits = jnp.where(ok[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
