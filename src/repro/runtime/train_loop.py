"""Checkpointed, failure-tolerant training loop.

Fault-tolerance contract (designed for 1000+ node fleets, exercised on
CPU in tests/examples):

* **checkpoint/restart** — atomic checkpoints every ``ckpt_every`` steps
  (repro.checkpoint); on start the loop resumes from the newest committed
  step, and the counter-based data pipeline replays the exact stream.
* **step retry** — transient step failures (injected via ``fault_hook``
  in tests; XLA/runtime errors in production) are retried up to
  ``max_retries`` times; persistent failure restores the last checkpoint
  before re-raising (so a supervisor restart continues cleanly).
* **NaN circuit-breaker** — a non-finite loss rolls back to the last
  checkpoint and skips the offending data step (recorded in metrics).
* **straggler mitigation** — the data iterator is wrapped by a deadline
  policy (runtime/straggler.py): batches arriving after the deadline are
  replaced by the stand-in batch for that step so the step clock never
  stalls on a slow host.
* **elastic rescheduling** — on device loss, `core.elastic` recomputes
  the LBLP placement for the surviving fleet (demonstrated in
  examples/elastic_reschedule.py at the scheduler tier; the LM tier
  re-jits on a shrunken mesh).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import LMConfig, ShapeSpec
from repro.data.pipeline import DataConfig, DataIterator
from repro.models.lm import model, transformer
from repro.optim import adamw


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    max_retries: int = 2
    log_every: int = 10
    opt: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)


@dataclass
class TrainReport:
    steps_run: int
    final_step: int
    resumed_from: Optional[int]
    losses: List[float]
    retries: int
    rollbacks: int
    wall_seconds: float


def train(cfg: LMConfig, shape: ShapeSpec, loop: TrainLoopConfig,
          data_cfg: Optional[DataConfig] = None,
          fault_hook: Optional[Callable[[int], None]] = None,
          mesh=None) -> TrainReport:
    """Run (or resume) training; returns a report for tests/examples."""
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    opt_state = adamw.init(params)

    state_like = {"params": params, "opt": opt_state}
    resumed_from = None
    start_step = 0
    restored = ckpt.restore_latest(loop.ckpt_dir, state_like)
    if restored is not None:
        start_step, state, _ = restored
        params, opt_state = state["params"], state["opt"]
        resumed_from = start_step

    tcfg = model.TrainStepConfig(opt=loop.opt)
    step_fn = jax.jit(model.make_train_step(cfg, tcfg, mesh=mesh))

    data = DataIterator(cfg, shape, start_step=start_step, dcfg=data_cfg)
    losses: List[float] = []
    retries = rollbacks = 0
    step = start_step

    def save(step, params, opt_state):
        ckpt.save(loop.ckpt_dir, step, {"params": params, "opt": opt_state},
                  extras={"arch": cfg.name})
        ckpt.prune(loop.ckpt_dir, keep=loop.ckpt_keep)

    if restored is None:
        save(0, params, opt_state)

    while step < loop.total_steps:
        batch = next(data)
        attempt = 0
        while True:
            try:
                if fault_hook is not None:
                    fault_hook(step)
                new_params, new_opt, metrics = step_fn(params, opt_state,
                                                       batch)
                loss = float(metrics["loss"])
                if not jnp.isfinite(jnp.asarray(loss)):
                    raise FloatingPointError(f"non-finite loss at {step}")
                params, opt_state = new_params, new_opt
                break
            except FloatingPointError:
                # NaN circuit breaker: rollback + skip the data step
                rollbacks += 1
                restored = ckpt.restore_latest(loop.ckpt_dir, state_like)
                if restored is not None:
                    _, state, _ = restored
                    params, opt_state = state["params"], state["opt"]
                loss = float("nan")
                break
            except Exception:
                attempt += 1
                retries += 1
                if attempt > loop.max_retries:
                    # persistent failure: leave a consistent checkpoint
                    save(step, params, opt_state)
                    raise
        losses.append(loss)
        step += 1
        if step % loop.ckpt_every == 0 or step == loop.total_steps:
            save(step, params, opt_state)
        if loop.log_every and step % loop.log_every == 0:
            print(f"[train] step={step} loss={loss:.4f}")

    return TrainReport(
        steps_run=step - start_step,
        final_step=step,
        resumed_from=resumed_from,
        losses=losses,
        retries=retries,
        rollbacks=rollbacks,
        wall_seconds=time.time() - t0,
    )
