"""Batched serving loop: continuous-batching-style request scheduler over
the prefill/decode steps.

Requests arrive with prompts; the server packs up to ``max_batch`` of
them, prefills once, then decodes in lockstep, retiring sequences on EOS
or length budget and refilling free slots from the queue (slot refill
re-prefills the packed batch — the jnp analogue of continuous batching
at fixed batch shape, which is what fixed-shape jit serving does in
production).  Fault tolerance: a decode-step failure re-runs prefill for
the live slots (caches are reconstructible state, never durable)."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models.lm import model


@dataclass
class Request:
    rid: int
    prompt: jnp.ndarray          # (S,) int32
    max_new: int = 16
    eos: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeStats:
    served: int = 0
    prefills: int = 0
    decode_steps: int = 0
    retries: int = 0
    wall_seconds: float = 0.0


class Server:
    def __init__(self, cfg: LMConfig, params, max_batch: int = 4,
                 s_max: int = 128, fault_hook=None) -> None:
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.s_max = s_max
        self.prefill = jax.jit(model.make_prefill_step(cfg, s_max=s_max))
        self.decode = jax.jit(model.make_decode_step(cfg))
        self.fault_hook = fault_hook

    def _pad_prompts(self, reqs: List[Request]) -> jnp.ndarray:
        width = max(int(r.prompt.shape[0]) for r in reqs)
        rows = []
        for r in reqs:
            pad = width - int(r.prompt.shape[0])
            rows.append(jnp.pad(r.prompt, (pad, 0)))   # left-pad
        return jnp.stack(rows)

    def serve(self, requests: List[Request]) -> ServeStats:
        t0 = time.time()
        stats = ServeStats()
        queue = list(requests)
        while queue:
            live = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            self._run_batch(live, stats)
            stats.served += len(live)
        stats.wall_seconds = time.time() - t0
        return stats

    def _run_batch(self, live: List[Request], stats: ServeStats) -> None:
        tokens = self._pad_prompts(live)
        logits, cache = self.prefill(self.params, {"tokens": tokens})
        stats.prefills += 1
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new for r in live)
        for step in range(max_new):
            for i, r in enumerate(live):
                if not r.done and len(r.out_tokens) < r.max_new:
                    tok = int(cur[i, 0])
                    r.out_tokens.append(tok)
                    if r.eos is not None and tok == r.eos:
                        r.done = True
                elif len(r.out_tokens) >= r.max_new:
                    r.done = True
            if all(r.done for r in live):
                break
            try:
                if self.fault_hook is not None:
                    self.fault_hook(stats.decode_steps)
                logits, cache = self.decode(self.params, cur, cache)
            except RuntimeError:
                # decode failure: caches are reconstructible — re-prefill
                # with everything generated so far and continue
                stats.retries += 1
                ext = []
                for i, r in enumerate(live):
                    gen = jnp.asarray(r.out_tokens, jnp.int32)
                    ext.append(jnp.concatenate([live[i].prompt, gen]))
                tokens = self._pad_prompts(
                    [Request(r.rid, e, r.max_new) for r, e in zip(live, ext)])
                logits, cache = self.prefill(self.params, {"tokens": tokens})
                stats.prefills += 1
            stats.decode_steps += 1
            cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
