"""Straggler mitigation: deadline-based batch substitution.

On large fleets the step clock must never stall on one slow host /
data shard.  Policy implemented here (the synchronous-SGD analogue of
backup workers):

* each step has a soft deadline (EMA of recent step times x slack);
* a batch that misses the deadline is *dropped* and replaced by the
  deterministic stand-in batch for that step (counter-based pipeline =>
  every host can generate it locally, no coordination needed);
* drop events are counted and exposed; persistent stragglers trigger the
  elastic path (core.elastic) instead of unbounded drops.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, Optional

from repro.configs.base import LMConfig, ShapeSpec
from repro.data.pipeline import DataConfig, make_batch


@dataclass
class StragglerPolicy:
    slack: float = 3.0            # deadline = slack * EMA(step time)
    ema_alpha: float = 0.2
    min_deadline_s: float = 0.05
    escalate_after: int = 8       # consecutive drops -> escalate

    ema: float = field(default=0.0, init=False)
    drops: int = field(default=0, init=False)
    consecutive: int = field(default=0, init=False)
    escalations: int = field(default=0, init=False)

    def deadline(self) -> float:
        return max(self.min_deadline_s, self.slack * self.ema)

    def observe(self, dt: float) -> None:
        self.ema = dt if self.ema == 0.0 else \
            (1 - self.ema_alpha) * self.ema + self.ema_alpha * dt

    def record_drop(self) -> bool:
        """Returns True when the caller should escalate (reschedule)."""
        self.drops += 1
        self.consecutive += 1
        if self.consecutive >= self.escalate_after:
            self.escalations += 1
            self.consecutive = 0
            return True
        return False

    def record_ok(self) -> None:
        self.consecutive = 0


class DeadlineDataIterator:
    """Wraps a (possibly slow) batch source with the deadline policy."""

    def __init__(self, cfg: LMConfig, shape: ShapeSpec,
                 source: Iterator, policy: Optional[StragglerPolicy] = None,
                 dcfg: Optional[DataConfig] = None,
                 on_escalate: Optional[Callable[[], None]] = None) -> None:
        self.cfg = cfg
        self.shape = shape
        self.source = source
        self.policy = policy or StragglerPolicy()
        self.dcfg = dcfg or DataConfig()
        self.on_escalate = on_escalate
        self.step = getattr(source, "step", 0)

    def __iter__(self):
        return self

    def __next__(self) -> Dict:
        t0 = time.time()
        deadline = self.policy.deadline()
        batch = next(self.source)
        dt = time.time() - t0
        if self.policy.ema > 0.0 and dt > deadline:
            # too late: substitute the deterministic stand-in for THIS step
            # (the slow batch is discarded; the step clock advances)
            batch = make_batch(self.cfg, self.shape, self.step, self.dcfg)
            if self.policy.record_drop() and self.on_escalate is not None:
                self.on_escalate()
        else:
            self.policy.record_ok()
            self.policy.observe(dt)
        self.step += 1
        return batch
