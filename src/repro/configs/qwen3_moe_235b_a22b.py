"""qwen3-moe-235b-a22b [moe] — 94L d4096 64H (GQA kv=4) d_ff=1536/expert,
vocab 151936, MoE 128 experts top-8 [assignment; hf:Qwen/Qwen3 family].

head_dim follows d_model//n_heads = 64 (assignment geometry; the hf
Qwen3 uses an explicit 128 — noted in DESIGN.md)."""

from .base import LMConfig, Segment

CONFIG = LMConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    segments=(Segment("attn", 94),),
    n_experts=128,
    top_k=8,
    act="silu",
    fsdp=True,
    microbatch=16,
)
