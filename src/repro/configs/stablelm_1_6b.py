"""stablelm-1.6b [dense] — 24L d2048 32H (MHA kv=32) d_ff=5632,
vocab 100352 [assignment; hf:stabilityai/stablelm-2-1_6b]."""

from .base import LMConfig, Segment

CONFIG = LMConfig(
    name="stablelm-1.6b",
    family="dense",
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    segments=(Segment("attn", 24),),
    act="silu",
    microbatch=64,
)
