"""Arch config registry: one module per assigned architecture."""

from __future__ import annotations

from importlib import import_module

from .base import GLOBAL_WINDOW, LMConfig, Segment, ShapeSpec, SHAPES, \
    shape_supported

_ARCH_MODULES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "stablelm-1.6b": "stablelm_1_6b",
    "gemma3-1b": "gemma3_1b",
    "gemma2-27b": "gemma2_27b",
    "starcoder2-3b": "starcoder2_3b",
    "whisper-small": "whisper_small",
    "paligemma-3b": "paligemma_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def get_config(arch: str) -> LMConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch '{arch}'; have {sorted(_ARCH_MODULES)}")
    mod = import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def all_archs() -> list:
    return sorted(_ARCH_MODULES)


__all__ = ["LMConfig", "Segment", "ShapeSpec", "SHAPES", "GLOBAL_WINDOW",
           "shape_supported", "get_config", "all_archs"]
