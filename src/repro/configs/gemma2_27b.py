"""gemma2-27b [dense] — 46L d4608 32H (GQA kv=16) d_ff=36864,
vocab 256000, local(4096)/global alternating, logit softcapping
[assignment; arXiv:2408.00118]."""

from .base import GLOBAL_WINDOW, LMConfig, Segment

CONFIG = LMConfig(
    name="gemma2-27b",
    family="dense",
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    segments=(Segment("attn", 46,
                      window_pattern=(4096, GLOBAL_WINDOW)),),
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    supports_long=True,        # half the layers are 4096-window local
    fsdp=True,
    microbatch=32,
)
