"""starcoder2-3b [dense] — 30L d3072 24H (GQA kv=2) d_ff=12288,
vocab 49152, GQA + RoPE, plain GeLU MLP [assignment; arXiv:2402.19173]."""

from .base import LMConfig, Segment

CONFIG = LMConfig(
    name="starcoder2-3b",
    family="dense",
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    segments=(Segment("attn", 30),),
    mlp_kind="plain",
    act="gelu",
    microbatch=16,
)
