"""Architecture config system.

``LMConfig`` fully describes one assigned architecture: geometry, layer
segments (scan groups), attention pattern, MoE/SSM/recurrent settings,
modality frontend stubs, and the parallelism plan.  Each
``src/repro/configs/<arch>.py`` exports ``CONFIG`` built from the
assignment's exact numbers plus ``CONFIG.smoke()`` for CPU tests.

Layer *segments*: a model is an ordered list of segments; each segment is
one ``lax.scan`` over stacked layer parameters (compile time O(1) in
depth).  A segment's per-layer attention window pattern is passed as scan
xs, so mixed local/global stacks (gemma2/gemma3) share one scan body.
Hybrid models (recurrentgemma) use a super-block segment whose body holds
multiple sub-blocks of different types.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

GLOBAL_WINDOW = 1 << 30     # "window" value meaning full/global attention


@dataclass(frozen=True)
class Segment:
    """One scanned group of layers.

    kind: 'attn' (attention+FFN; FFN is MoE when cfg.n_experts>0),
          'ssm' (mamba block), 'rec' (RG-LRU block + FFN),
          'hybrid3' (super-block: rec, rec, attn-local — recurrentgemma),
          'xattn' (decoder layer with self+cross attention — whisper dec).
    n: number of layers (super-blocks for 'hybrid3') in the scan.
    window_pattern: per-layer sliding windows, cycled to length n
        (GLOBAL_WINDOW = full attention).  Only used by attention kinds.
    """

    kind: str
    n: int
    window_pattern: Tuple[int, ...] = (GLOBAL_WINDOW,)

    def windows(self) -> Tuple[int, ...]:
        p = self.window_pattern
        return tuple(p[i % len(p)] for i in range(self.n))


@dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: Tuple[Segment, ...]
    head_dim: Optional[int] = None    # default d_model // n_heads
    # attention extras
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"           # rope | learned
    # ffn
    mlp_kind: str = "gated"           # gated | plain
    act: str = "silu"
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm / recurrent
    ssm_state: int = 0
    d_inner: int = 0                  # ssm/rglru inner width
    dt_rank: int = 0
    conv_k: int = 4
    # enc-dec (whisper): encoder stack config
    enc_segments: Tuple[Segment, ...] = ()
    enc_frame_dim: int = 0            # stub frontend: precomputed frame embs
    dec_len_ratio: int = 8            # dec_len = seq_len // ratio
    # vlm (paligemma): stub image prefix
    num_prefix_tokens: int = 0
    prefix_dim: int = 0
    norm_kind: str = "rms"            # rms | ln
    # training plan
    fsdp: bool = False                # shard params/opt-state over data too
    microbatch: int = 32              # per-gradient-accumulation-step batch
    remat: bool = True
    scan_unroll: bool = False     # full-unroll scans (exact dry-run cost)
    chunk_scan: bool = True       # lax.scan q-chunks (False: python loop, exact cost)
    tie_embeddings: bool = True
    # which shapes this arch supports (skips documented in DESIGN.md)
    supports_long: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return sum(s.n * (3 if s.kind == "hybrid3" else 1)
                   for s in self.segments)

    def is_encdec(self) -> bool:
        return bool(self.enc_segments)

    # -- reduced variant for CPU smoke tests -------------------------------
    def smoke(self) -> "LMConfig":
        def shrink_seg(s: Segment) -> Segment:
            return replace(s, n=min(s.n, 2),
                           window_pattern=tuple(min(w, 64) if w < GLOBAL_WINDOW
                                                else w
                                                for w in s.window_pattern))

        return replace(
            self,
            name=self.name + "-smoke",
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab=256,
            segments=tuple(shrink_seg(s) for s in self.segments),
            enc_segments=tuple(shrink_seg(s) for s in self.enc_segments),
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_inner=128 if self.d_inner else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            dt_rank=8 if self.dt_rank else 0,
            enc_frame_dim=64 if self.enc_frame_dim else 0,
            num_prefix_tokens=min(self.num_prefix_tokens, 8),
            prefix_dim=64 if self.prefix_dim else 0,
            microbatch=4,
        )


# ---------------------------------------------------------------------------
# input shapes (the assignment's four shapes)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_supported(cfg: LMConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(supported, reason-if-not) — the documented skip rules."""
    if shape.name == "long_500k" and not cfg.supports_long:
        return False, ("pure full-attention arch: 500k decode KV is "
                       "quadratic-history; skipped per assignment")
    return True, ""
