"""paligemma-3b [vlm] — 18L d2048 8H (MQA kv=1) d_ff=16384,
vocab 257216; SigLIP frontend STUBBED: input_specs provides 256
precomputed patch embeddings (B, 256, 2048) [assignment;
arXiv:2407.07726]."""

from .base import LMConfig, Segment

CONFIG = LMConfig(
    name="paligemma-3b",
    family="vlm",
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    segments=(Segment("attn", 18),),
    num_prefix_tokens=256,
    prefix_dim=2048,
    act="gelu",
    microbatch=32,
)
