"""gemma3-1b [dense] — 26L d1152 4H (MQA kv=1) d_ff=6912, vocab 262144,
5 local (512-window) : 1 global pattern, 128k-class context
[assignment; hf:google/gemma-3-1b-pt]."""

from .base import GLOBAL_WINDOW, LMConfig, Segment

CONFIG = LMConfig(
    name="gemma3-1b",
    family="dense",
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    segments=(Segment("attn", 26,
                      window_pattern=(512, 512, 512, 512, 512,
                                      GLOBAL_WINDOW)),),
    act="gelu",
    rope_theta=1_000_000.0,
    supports_long=True,        # 5/6 of layers are 512-window local
    microbatch=64,
)
