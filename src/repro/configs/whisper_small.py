"""whisper-small [audio] — 12L encoder + 12L decoder, d768 12H (MHA)
d_ff=3072, vocab 51865; conv frontend STUBBED: input_specs provides
precomputed frame embeddings (B, frames, 768); sinusoidal positions
[assignment; arXiv:2212.04356]."""

from .base import LMConfig, Segment

CONFIG = LMConfig(
    name="whisper-small",
    family="audio",
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    segments=(Segment("xattn", 12),),          # decoder
    enc_segments=(Segment("attn", 12),),       # encoder (non-causal)
    enc_frame_dim=768,
    dec_len_ratio=8,
    mlp_kind="plain",
    act="gelu",
    pos_embed="sinusoid",
    norm_kind="ln",
    microbatch=64,
)
