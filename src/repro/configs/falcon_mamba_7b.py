"""falcon-mamba-7b [ssm] — 64L d4096, attn-free mamba1, ssm_state=16,
vocab 65024 [assignment; arXiv:2410.05355]."""

from .base import LMConfig, Segment

CONFIG = LMConfig(
    name="falcon-mamba-7b",
    family="ssm",
    d_model=4096,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    segments=(Segment("ssm", 64),),
    ssm_state=16,
    d_inner=8192,
    dt_rank=256,
    conv_k=4,
    supports_long=True,
    microbatch=16,
)
