"""recurrentgemma-9b [hybrid] — 38L d4096 16H (MQA kv=1) d_ff=12288,
vocab 256000; RG-LRU + local attention at 2:1 (pattern rec,rec,attn):
12 super-blocks of 3 + 2 trailing recurrent layers = 38
[assignment; arXiv:2402.19427]."""

from .base import LMConfig, Segment

CONFIG = LMConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    segments=(Segment("hybrid3", 12, window_pattern=(2048,)),
              Segment("rec", 2)),
    d_inner=4096,
    conv_k=4,
    act="gelu",
    supports_long=True,
    microbatch=16,
)
