"""granite-moe-3b-a800m [moe] — 32L d1536 24H (GQA kv=8) d_ff=512/expert,
vocab 49155, MoE 40 experts top-8 [assignment; hf:ibm-granite family]."""

from .base import LMConfig, Segment

CONFIG = LMConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    segments=(Segment("attn", 32),),
    n_experts=40,
    top_k=8,
    act="silu",
    microbatch=16,
)
