"""Deterministic synthetic data pipeline with distributed semantics.

Produces next-token-prediction batches (and modality-stub inputs) from a
counter-based PRNG, so:
* every (step, host) pair regenerates identical data — restart-safe
  without data-loader checkpoints (the loader state IS the step number);
* per-host sharding: host h of H draws rows [h*B/H, (h+1)*B/H) of the
  global batch, matching jax.make_array_from_process-style loading on a
  real multi-host pod;
* an optional "straggler" hook simulates slow shards for the mitigation
  policy tests (runtime/straggler.py).

A light Zipf-ish token distribution plus a copy-structure (spans repeated
within a sequence) make the synthetic stream *learnable*, so training
losses decrease and convergence tests are meaningful.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig, ShapeSpec


@dataclass
class DataConfig:
    seed: int = 1234
    zipf_alpha: float = 1.1
    copy_span: int = 64       # repeated span length (learnable structure)
    num_hosts: int = 1
    host_id: int = 0


def _token_batch(cfg: LMConfig, rows: int, seq: int, step: int,
                 dcfg: DataConfig) -> np.ndarray:
    """Counter-based deterministic token generation (numpy, host-side)."""
    rng = np.random.default_rng(
        np.uint64(dcfg.seed) + np.uint64(step) * np.uint64(1_000_003)
        + np.uint64(dcfg.host_id) * np.uint64(7_919))
    # Zipf-ish marginal over the vocab via inverse-power transform
    u = rng.random((rows, seq))
    ranks = np.floor((cfg.vocab - 1) * u ** dcfg.zipf_alpha).astype(np.int64)
    toks = ranks % cfg.vocab
    # inject copy structure: second span repeats the first
    span = min(dcfg.copy_span, seq // 2)
    if span > 0:
        toks[:, span:2 * span] = toks[:, :span]
    return toks.astype(np.int32)


def make_batch(cfg: LMConfig, shape: ShapeSpec, step: int,
               dcfg: Optional[DataConfig] = None) -> Dict[str, jnp.ndarray]:
    """Batch for this host at ``step`` (host's slice of the global batch)."""
    dcfg = dcfg or DataConfig()
    B = shape.global_batch // dcfg.num_hosts
    S = shape.seq_len
    if cfg.is_encdec():
        dec = max(S // cfg.dec_len_ratio, 8)
        rng = np.random.default_rng(dcfg.seed + step)
        frames = rng.standard_normal((B, S, cfg.enc_frame_dim),
                                     dtype=np.float32)
        toks = _token_batch(cfg, B, dec, step, dcfg)
        return {"enc_frames": jnp.asarray(frames, jnp.bfloat16),
                "tokens": jnp.asarray(toks),
                "labels": jnp.asarray(toks)}
    if cfg.num_prefix_tokens:
        text = S - cfg.num_prefix_tokens
        rng = np.random.default_rng(dcfg.seed + step)
        prefix = rng.standard_normal(
            (B, cfg.num_prefix_tokens, cfg.prefix_dim), dtype=np.float32)
        toks = _token_batch(cfg, B, text, step, dcfg)
        return {"prefix": jnp.asarray(prefix, jnp.bfloat16),
                "tokens": jnp.asarray(toks),
                "labels": jnp.asarray(toks)}
    toks = _token_batch(cfg, B, S, step, dcfg)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


class DataIterator:
    """Stateless-resumable iterator: ``DataIterator(cfg, shape, start_step)``
    regenerates exactly the stream a crashed run would have continued."""

    def __init__(self, cfg: LMConfig, shape: ShapeSpec, start_step: int = 0,
                 dcfg: Optional[DataConfig] = None,
                 delay_fn: Optional[Callable[[int], float]] = None) -> None:
        self.cfg = cfg
        self.shape = shape
        self.step = start_step
        self.dcfg = dcfg or DataConfig()
        self.delay_fn = delay_fn      # straggler simulation hook

    def __iter__(self) -> "DataIterator":
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        if self.delay_fn is not None:
            d = self.delay_fn(self.step)
            if d > 0:
                time.sleep(d)
        batch = make_batch(self.cfg, self.shape, self.step, self.dcfg)
        self.step += 1
        return batch
