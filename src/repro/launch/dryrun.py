import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
on the production meshes, record memory/cost/collective analyses.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.  Do
not set that flag anywhere global (tests/benches see the real host).

Per cell this produces (artifacts/dryrun/<arch>__<shape>__<mesh>.json):
  * compile success + wall time,
  * memory_analysis (per-device argument/output/temp bytes),
  * exact FLOPs / bytes via E/B scan-decomposition (XLA cost analysis
    counts a while-loop body once, so we compile an all-segments-at-1
    base and per-segment at-2 variants:
    corrected = f(all=1) + sum_seg (n_seg - 1) * B_seg, x n_microbatches
    for train — cross-validated against full-unroll compiles and
    first-principles analytics),
  * per-collective byte totals parsed from the optimized HLO, corrected
    the same way.

Usage:
  python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

# The XLA env flag above must be set before anything imports jax,
# hence module code precedes the imports.
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES, all_archs, get_config  # noqa: E402
from repro.configs.base import LMConfig, ShapeSpec, shape_supported  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.lm import model, sharding  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
    "u16": 2, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\]\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective kind from optimized HLO."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        size = _DTYPE_BYTES.get(dtype, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out[kind] = out.get(kind, 0.0) + size * n
    return out


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def _variant(cfg: LMConfig, seg_counts: Dict[int, int],
             enc_counts: Optional[Dict[int, int]] = None) -> LMConfig:
    """Config with per-segment layer counts overridden."""
    segs = tuple(dataclasses.replace(s, n=seg_counts.get(i, 0))
                 for i, s in enumerate(cfg.segments))
    encs = cfg.enc_segments
    if encs:
        enc_counts = enc_counts or {}
        encs = tuple(dataclasses.replace(s, n=enc_counts.get(i, 0))
                     for i, s in enumerate(encs))
    return dataclasses.replace(cfg, segments=segs, enc_segments=encs)


def build_step(cfg: LMConfig, shape: ShapeSpec, mesh,
               single_microbatch: bool = False):
    """Returns (jitted_fn, abstract_args) for the cell."""
    rep = sharding.replicated(mesh)
    aparams = model.abstract_params(cfg)
    ps = sharding.param_shardings(cfg, mesh, aparams)

    if shape.mode == "train":
        eff_shape = shape
        if single_microbatch:
            eff_shape = dataclasses.replace(
                shape, global_batch=min(cfg.microbatch, shape.global_batch))
        aopt = model.abstract_opt_state(cfg)
        batch_spec = model.make_batch_spec(cfg, eff_shape)
        os_ = sharding.opt_shardings(cfg, mesh, aopt, aparams)
        bs = sharding.batch_shardings(mesh, batch_spec)
        step = model.make_train_step(cfg, mesh=mesh)
        met = {"loss": rep, "grad_norm": rep, "lr": rep}
        fn = jax.jit(step, in_shardings=(ps, os_, bs),
                     out_shardings=(ps, os_, met), donate_argnums=(0, 1))
        return fn, (aparams, aopt, batch_spec)

    if shape.mode == "prefill":
        batch_spec = model.make_batch_spec(cfg, shape)
        bs = sharding.batch_shardings(mesh, batch_spec)
        acache = model.init_cache_spec(cfg, shape)
        cs = sharding.cache_shardings(mesh, acache)
        step = model.make_prefill_step(cfg, s_max=shape.seq_len)
        fn = jax.jit(step, in_shardings=(ps, bs),
                     out_shardings=(rep, cs))
        return fn, (aparams, batch_spec)

    # decode
    batch_spec = model.make_batch_spec(cfg, shape)
    bs = sharding.batch_shardings(mesh, batch_spec)
    acache = model.init_cache_spec(cfg, shape)
    cs = sharding.cache_shardings(mesh, acache)
    step = model.make_decode_step(cfg)
    fn = jax.jit(step, in_shardings=(ps, bs["token"], cs),
                 out_shardings=(rep, cs), donate_argnums=(2,))
    return fn, (aparams, batch_spec["token"], acache)


def compile_cell(cfg: LMConfig, shape: ShapeSpec, mesh,
                 single_microbatch: bool = False):
    fn, args = build_step(cfg, shape, mesh, single_microbatch)
    # activation sharding constraints apply while tracing
    with sharding.activation_mesh(mesh):
        lowered = fn.lower(*args)
    compiled = lowered.compile()
    return compiled


def cost_of(compiled) -> Tuple[float, float, Dict[str, float]]:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text())
    return flops, byts, coll


def corrected_costs(cfg: LMConfig, shape: ShapeSpec, mesh) -> Dict:
    """E/B decomposition: exact flops/bytes/collectives despite rolled
    scans.

    E = 0-layer program; B_i = f(only segment i at 1 layer) - E;
    total = E + sum_i n_i * B_i  (x n_microbatches for train).

    jax emits a while loop even for scan length 1, so every variant
    counts each scan body exactly once — the decomposition is exact for
    FLOPs and was validated against a full-unroll compile and hand
    analytics (stablelm train: 5.80e13 vs 6.3e13 unrolled, the gap being
    unroll-mode fusion double-counting).  Bytes/collective deltas are
    clamped at >= 0: XLA:CPU fusion noise can make a 1-layer program
    report marginally fewer pre-fusion bytes than the 0-layer one.
    """
    n_mb = 1
    if shape.mode == "train":
        n_mb = max(shape.global_batch // min(cfg.microbatch,
                                             shape.global_batch), 1)

    cfg = dataclasses.replace(cfg, chunk_scan=False)  # exact chunk flops
    zero = _variant(cfg, {}, {})
    e_flops, e_bytes, e_coll = cost_of(
        compile_cell(zero, shape, mesh, single_microbatch=True))

    flops, byts = e_flops, e_bytes
    coll = dict(e_coll)
    per_seg = []

    def add_segment(kind_label, n_layers, one_cfg):
        nonlocal flops, byts, coll
        f1, b1, c1 = cost_of(
            compile_cell(one_cfg, shape, mesh, single_microbatch=True))
        bf = max(f1 - e_flops, 0.0)
        bb = max(b1 - e_bytes, 0.0)
        per_seg.append({"kind": kind_label, "n": n_layers,
                        "body_flops": bf, "body_bytes": bb})
        flops += n_layers * bf
        byts += n_layers * bb
        for k in set(c1) | set(coll):
            delta = max(c1.get(k, 0.0) - e_coll.get(k, 0.0), 0.0)
            coll[k] = coll.get(k, 0.0) + n_layers * delta

    for i, seg in enumerate(cfg.segments):
        add_segment(seg.kind, seg.n, _variant(cfg, {i: 1}, {}))
    for i, seg in enumerate(cfg.enc_segments):
        add_segment("enc:" + seg.kind, seg.n, _variant(cfg, {}, {i: 1}))

    return {
        "n_microbatches": n_mb,
        "flops_per_device": flops * n_mb,
        "bytes_per_device": byts * n_mb,
        "collective_bytes_per_device": {k: v * n_mb for k, v in coll.items()},
        "segments": per_seg,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             with_cost: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ok, reason = shape_supported(cfg, shape)
    result: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        result.update(status="skipped", reason=reason)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    compiled = compile_cell(cfg, shape, mesh)
    compile_s = time.time() - t0
    ma = compiled.memory_analysis()
    result.update(
        status="ok",
        compile_seconds=compile_s,
        devices=mesh.devices.size,
        memory={
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes),
        },
    )
    if with_cost:
        t1 = time.time()
        result["cost"] = corrected_costs(cfg, shape, mesh)
        result["cost_seconds"] = time.time() - t1
    return result


def cell_path(arch: str, shape_name: str, mesh_name: str) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    return os.path.join(ART_DIR, f"{arch}__{shape_name}__{mesh_name}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="compile-only (multi-pod pass)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = all_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]

    failures = []
    for multi_pod in meshes:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        for arch in archs:
            for shape_name in shapes:
                path = cell_path(arch, shape_name, mesh_name)
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {arch} x {shape_name} x {mesh_name}")
                    continue
                print(f"[run   ] {arch} x {shape_name} x {mesh_name} ...",
                      flush=True)
                try:
                    res = run_cell(arch, shape_name, multi_pod,
                                   with_cost=not args.no_cost)
                except Exception as e:  # record failures as data
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "failed",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    failures.append((arch, shape_name, mesh_name))
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)
                status = res["status"]
                extra = ""
                if status == "ok":
                    mem = res["memory"]["peak_estimate_bytes"] / 2**30
                    extra = (f" compile={res['compile_seconds']:.1f}s "
                             f"peak/device={mem:.2f}GiB")
                print(f"[{status:7s}] {arch} x {shape_name} x {mesh_name}"
                      f"{extra}", flush=True)
    if failures:
        print(f"\nFAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells done")


if __name__ == "__main__":
    main()
