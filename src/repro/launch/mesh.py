"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import to materialize the placeholder devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) for two
    pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers (CPU tests / examples): (1, n_devices)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
