"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape) on the single-pod mesh, derive the three terms:

    compute    = HLO_FLOPs_per_device      / peak_FLOPs      (197e12 bf16)
    memory     = HLO_bytes_per_device      / HBM_bw          (819e9 B/s)
    collective = collective_bytes_per_dev  / ICI_bw          (3 links x 50e9)

HLO_FLOPs/bytes come from the E/B-corrected cost analysis (dryrun.py);
collective bytes from the optimized-HLO parse.  MODEL_FLOPS uses
6*N*D (dense) / 6*N_active*D (MoE) for training, 2*N(/active)*D for
inference, D = tokens processed.  The utilization column is
MODEL_FLOPS / (chips * peak * dominant_term): the fraction of roofline
the step achieves if it runs exactly at its bottleneck term.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--markdown]
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import dataclass
from typing import Dict, List

from repro.configs import SHAPES, all_archs, get_config
from repro.models.lm import transformer

PEAK_FLOPS = 197e12          # bf16 / chip (v5e-class)
HBM_BW = 819e9               # B/s / chip
ICI_LINK_BW = 50e9           # B/s / link
ICI_LINKS = 3                # links per chip on a 2D torus mesh slice

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """6*N_active*tokens for train, 2*N_active*tokens for inference."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = transformer.param_count(cfg)
    if cfg.n_experts:
        n -= (cfg.n_experts - cfg.top_k) * cfg.n_layers * 3 \
            * cfg.d_model * cfg.d_ff
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_encdec():
            tokens = shape.global_batch * (shape.seq_len // cfg.dec_len_ratio)
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_encdec():
            tokens = shape.global_batch * (shape.seq_len // cfg.dec_len_ratio)
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


@dataclass
class RooflineRow:
    arch: str
    shape: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0               # HLO "bytes accessed" (pre-fusion
                                        # on XLA:CPU -> pessimistic bound)
    memory_fused_s: float = 0.0         # analytic fused-traffic model
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    hlo_flops_total: float = 0.0
    useful_ratio: float = 0.0           # MODEL_FLOPS / total HLO flops
    roofline_fraction: float = 0.0      # model-flops-time / dominant term
    peak_gib: float = 0.0
    note: str = ""


def fused_memory_bytes(arch: str, shape_name: str) -> float:
    """Coarse fused HBM-traffic model per device per step (XLA:CPU's
    cost analysis reports *pre-fusion* operand bytes, which overcounts
    HBM traffic by orders of magnitude; this model counts what a fused
    TPU program actually moves: weight shards per pass, the remat stash,
    logits, and KV/state caches)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    devices = 256
    shards = devices if cfg.fsdp else 16        # TP / FSDP param sharding
    n_params = transformer.param_count(cfg)
    p_local = 2.0 * n_params / shards           # bf16 weight bytes/device
    mb = min(cfg.microbatch, shape.global_batch)
    n_mb = max(shape.global_batch // mb, 1)
    tok_local = mb * shape.seq_len / 16         # per data-shard tokens/mb
    d = cfg.d_model
    L = cfg.n_layers
    if shape.mode == "train":
        weight_passes = 4.0                      # fwd + remat + 2x bwd
        opt = 10.0 * 4.0 * n_params / shards     # f32 p/m/v read+write
        stash = 2.0 * L * tok_local * d * 2.0    # write + read, bf16
        logits = 2.0 * tok_local * cfg.vocab / 16 * 4.0
        act = 8.0 * tok_local * d * 2.0 * L      # block activations r/w
        return n_mb * (weight_passes * p_local + stash + logits + act) + opt
    if shape.mode == "prefill":
        tok_local = shape.global_batch * shape.seq_len / 16
        cache = 2.0 * L * tok_local * cfg.n_kv_heads * cfg.hd * 2.0 / 16
        act = 6.0 * tok_local * d * 2.0 * L / 16
        return p_local + cache + act
    # decode: read all weights + read/write cache
    cache = (2.0 * L * shape.global_batch * shape.seq_len
             * cfg.n_kv_heads * cfg.hd * 2.0) / devices
    return p_local + 2.0 * cache


def analyse(artifact: Dict) -> RooflineRow:
    arch, shape = artifact["arch"], artifact["shape"]
    if artifact["status"] != "ok":
        return RooflineRow(arch, shape, artifact["status"],
                           note=artifact.get("reason",
                                             artifact.get("error", ""))[:80])
    devices = artifact["devices"]
    cost = artifact.get("cost")
    if not cost:
        return RooflineRow(arch, shape, "no-cost")
    flops_dev = cost["flops_per_device"]
    bytes_dev = cost["bytes_per_device"]
    coll_dev = sum(cost["collective_bytes_per_device"].values())
    compute = flops_dev / PEAK_FLOPS
    memory = bytes_dev / HBM_BW
    memory_fused = fused_memory_bytes(arch, shape) / HBM_BW
    collective = coll_dev / (ICI_LINK_BW * ICI_LINKS)
    # bottleneck judged on the fused-traffic memory estimate (see
    # fused_memory_bytes docstring); the raw HLO bound is also reported
    dominant = max((compute, "compute"), (memory_fused, "memory"),
                   (collective, "collective"))[1]
    dom_t = max(compute, memory_fused, collective)
    mf = model_flops(arch, shape)
    hlo_total = flops_dev * devices
    ideal_t = mf / (devices * PEAK_FLOPS)
    return RooflineRow(
        arch=arch, shape=shape, status="ok",
        compute_s=compute, memory_s=memory, memory_fused_s=memory_fused,
        collective_s=collective,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        roofline_fraction=ideal_t / dom_t if dom_t else 0.0,
        peak_gib=artifact["memory"]["peak_estimate_bytes"] / 2**30,
    )


def suggest(row: RooflineRow) -> str:
    """One sentence on what would move the dominant term down."""
    if row.status != "ok":
        return ""
    if row.dominant == "compute":
        if row.useful_ratio < 0.5:
            return ("compute-bound with low useful ratio: cut remat "
                    "recompute (policy: save attn outputs) and MoE "
                    "capacity overhead")
        return ("compute-bound near the useful limit: only faster math "
                "(int8/fp8 matmuls) or more chips move this")
    if row.dominant == "memory":
        return ("memory-bound: fuse attention (Pallas flash kernel avoids "
                "logits round-trips), keep KV cache in bf16, widen "
                "per-step arithmetic intensity (larger microbatch)")
    return ("collective-bound: overlap all-reduce with backward compute, "
            "reduce-scatter gradients (FSDP), or INT8-compress "
            "(optim.compression) the gradient traffic")


def load_rows(mesh: str = "pod16x16") -> List[RooflineRow]:
    rows = []
    for arch in all_archs():
        for shape in SHAPES:
            path = os.path.join(ART_DIR, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(path):
                rows.append(RooflineRow(arch, shape, "missing"))
                continue
            rows.append(analyse(json.load(open(path))))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load_rows()

    if args.markdown:
        print("| arch | shape | compute s | mem(hlo) s | mem(fused) s |"
              " coll s | dominant | useful | roofline | peak GiB | note |")
        print("|---|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r.status != "ok":
                print(f"| {r.arch} | {r.shape} | - | - | - | - |"
                      f" {r.status} | - | - | - | {r.note} |")
                continue
            print(f"| {r.arch} | {r.shape} | {r.compute_s:.3e} |"
                  f" {r.memory_s:.3e} | {r.memory_fused_s:.3e} |"
                  f" {r.collective_s:.3e} |"
                  f" {r.dominant} | {r.useful_ratio:.2f} |"
                  f" {r.roofline_fraction:.2f} | {r.peak_gib:.1f} |"
                  f" {suggest(r)[:60]} |")
    else:
        hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} {'mem(hlo)':>10s}"
               f" {'mem(fused)':>10s} {'coll':>10s} {'dom':>10s}"
               f" {'useful':>7s} {'roofl':>6s}")
        print(hdr)
        for r in rows:
            if r.status != "ok":
                print(f"{r.arch:24s} {r.shape:12s} [{r.status}] {r.note}")
                continue
            print(f"{r.arch:24s} {r.shape:12s} {r.compute_s:10.3e}"
                  f" {r.memory_s:10.3e} {r.memory_fused_s:10.3e}"
                  f" {r.collective_s:10.3e}"
                  f" {r.dominant:>10s} {r.useful_ratio:7.2f}"
                  f" {r.roofline_fraction:6.2f}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([r.__dict__ for r in rows], f, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
