from . import ckpt
