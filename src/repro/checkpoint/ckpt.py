"""Fault-tolerant checkpointing: atomic, self-describing, resumable.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json     — tree structure, shapes, dtypes, step, extras
        arrays.npz        — flat leaf arrays (npz is zip: per-leaf entries)
    <dir>/step_000123.COMMITTED   — commit marker (atomic rename)

Write protocol: serialize into ``step_X.tmp/``, fsync, atomically rename
to ``step_X/``, then create the COMMITTED marker.  A crash at any point
leaves either a fully-committed checkpoint or ignorable garbage —
``latest_step`` only considers committed steps, so restart-after-failure
always resumes from a consistent state (deliverable: checkpoint/restart
fault tolerance).

Pytrees are restored with their original structure; bfloat16 is stored
as uint16 with a dtype tag (npz has no native bf16).
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path) or "leaf"
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):
        return str(k.name)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save(directory: str, step: int, tree, extras: Optional[Dict] = None
         ) -> str:
    """Atomically write checkpoint for ``step``; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    named = _flatten_with_names(tree)
    arrays = {}
    dtypes = {}
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            dtypes[name] = "bfloat16"
            arr = arr.view(np.uint16)
        else:
            dtypes[name] = str(arr.dtype)
        arrays[name] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)

    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "names": [n for n, _ in named],
        "dtypes": dtypes,
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(final + ".COMMITTED", "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    return final


def latest_step(directory: str) -> Optional[int]:
    """Highest committed step, or None."""
    if not os.path.isdir(directory):
        return None
    best = None
    for entry in os.listdir(directory):
        m = _STEP_RE.match(entry)
        if m and os.path.exists(os.path.join(directory, entry + ".COMMITTED")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore(directory: str, step: int, like) -> Tuple[Any, Dict]:
    """Restore the checkpoint into the structure of ``like`` (a pytree of
    arrays or ShapeDtypeStructs); returns (tree, extras)."""
    final = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "arrays.npz"))
    named = _flatten_with_names(like)
    leaves = []
    for name, leaf in named:
        arr = data[name]
        if manifest["dtypes"][name] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"checkpoint leaf {name}: shape {arr.shape} != {expect}")
        leaves.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extras"]


def restore_latest(directory: str, like) -> Optional[Tuple[int, Any, Dict]]:
    step = latest_step(directory)
    if step is None:
        return None
    tree, extras = restore(directory, step, like)
    return step, tree, extras


def prune(directory: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` committed checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(m.group(1)) for e in os.listdir(directory)
        if (m := _STEP_RE.match(e))
        and os.path.exists(os.path.join(directory, e + ".COMMITTED")))
    for s in steps[:-keep] if keep else steps:
        path = os.path.join(directory, f"step_{s:09d}")
        shutil.rmtree(path, ignore_errors=True)
        try:
            os.remove(path + ".COMMITTED")
        except OSError:
            pass
